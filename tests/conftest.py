import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# host's real (single) device; only launch/dryrun.py forces 512.


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
