import importlib.util
import os

import numpy as np
import pytest

# NOTE: no XLA_FLAGS here on purpose -- smoke tests and benches must see the
# host's real (single) device; only launch/dryrun.py forces 512.

# hypothesis is uninstallable on some hosts; fall back to a deterministic
# shim so the property-test modules still collect and run (see
# _hypothesis_compat.py).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "_hypothesis_compat",
        os.path.join(os.path.dirname(__file__), "_hypothesis_compat.py"))
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    _mod.install()


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
