"""Lagrange Coded Computing: the paper's central mechanism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field as F, lagrange


def _poly_f(x, w, coeffs):
    """f(X, w) = X^T ghat(Xw): degree 2r+1, the paper's Eq. 7."""
    z = F.matmul(x, w[:, None])[:, 0]
    g = F.evaluate_poly_dyn(coeffs, z)
    return F.matmul(x.T, g[:, None])[:, 0]


def _encode_model(rng, w, k, t, alphas, betas):
    """w~_i = v(alpha_i) with v(beta_1..K) = w (paper Eq. 4).  Using the
    CODED model matters: with a constant w the composed polynomial h(z)
    degenerates to degree 2(K+T-1) and fewer evaluations suffice."""
    wb = jnp.broadcast_to(w[None, None, :], (k, 1, w.shape[0]))
    vm = jnp.asarray(rng.integers(0, F.P, size=(t, 1, w.shape[0])
                                  ).astype(np.int32))
    return lagrange.lcc_encode(wb, vm, alphas, betas)[:, 0, :]   # (N, d)


@pytest.mark.parametrize("k,t,r", [(2, 1, 1), (3, 2, 1), (2, 1, 3)])
def test_encode_compute_decode_roundtrip(rng, k, t, r):
    """Decoding N coded evaluations of f recovers f(X_k, w) exactly."""
    n = lagrange.recovery_threshold(r, k, t) + 2     # a couple spare clients
    mk, d = 6, 4
    alphas, betas = lagrange.default_points(n, k, t)
    blocks = jnp.asarray(rng.integers(0, F.P, size=(k, mk, d)).astype(np.int32))
    masks = jnp.asarray(rng.integers(0, F.P, size=(t, mk, d)).astype(np.int32))
    coded = lagrange.lcc_encode(blocks, masks, alphas, betas)
    assert coded.shape == (n, mk, d)

    w = jnp.asarray(rng.integers(0, F.P, size=(d,)).astype(np.int32))
    wc = _encode_model(rng, w, k, t, alphas, betas)
    coeffs = jnp.asarray(rng.integers(0, F.P, size=(r + 1,)).astype(np.int32))
    evals = jnp.stack([_poly_f(coded[i], wc[i], coeffs) for i in range(n)])

    rthr = lagrange.recovery_threshold(r, k, t)
    decoded = lagrange.lcc_decode(evals[:rthr], alphas[:rthr], betas, k)
    expected = jnp.stack([_poly_f(blocks[i], w, coeffs) for i in range(k)])
    np.testing.assert_array_equal(np.asarray(decoded), np.asarray(expected))


def test_straggler_subsets_equivalent(rng):
    """ANY R of the N evaluations decode to the same result -- the paper's
    recovery threshold / our framework's straggler-mitigation claim."""
    k, t, r = 2, 1, 1
    rthr = lagrange.recovery_threshold(r, k, t)      # 3(K+T-1)+1 = 7
    n = rthr + 3
    alphas, betas = lagrange.default_points(n, k, t)
    blocks = jnp.asarray(rng.integers(0, F.P, size=(k, 4, 3)).astype(np.int32))
    masks = jnp.asarray(rng.integers(0, F.P, size=(t, 4, 3)).astype(np.int32))
    coded = lagrange.lcc_encode(blocks, masks, alphas, betas)
    w = jnp.asarray(rng.integers(0, F.P, size=(3,)).astype(np.int32))
    wc = _encode_model(rng, w, k, t, alphas, betas)
    coeffs = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    evals = jnp.stack([_poly_f(coded[i], wc[i], coeffs) for i in range(n)])

    ref = None
    for subset in [tuple(range(rthr)), tuple(range(3, 3 + rthr)),
                   (0, 2, 4, 5, 6, 8, 9)]:
        sub_alphas = [alphas[i] for i in subset]
        dec = lagrange.lcc_decode(evals[jnp.asarray(subset)],
                                  sub_alphas, betas, k)
        dec = np.asarray(dec)
        if ref is None:
            ref = dec
        else:
            np.testing.assert_array_equal(dec, ref)


def test_below_threshold_fails(rng):
    """R-1 evaluations must NOT decode correctly (threshold is tight)."""
    k, t, r = 2, 1, 1
    rthr = lagrange.recovery_threshold(r, k, t)
    n = rthr
    alphas, betas = lagrange.default_points(n, k, t)
    blocks = jnp.asarray(rng.integers(0, F.P, size=(k, 4, 3)).astype(np.int32))
    masks = jnp.asarray(rng.integers(0, F.P, size=(t, 4, 3)).astype(np.int32))
    coded = lagrange.lcc_encode(blocks, masks, alphas, betas)
    w = jnp.asarray(rng.integers(0, F.P, size=(3,)).astype(np.int32))
    wc = _encode_model(rng, w, k, t, alphas, betas)
    coeffs = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    evals = jnp.stack([_poly_f(coded[i], wc[i], coeffs) for i in range(n)])
    short = lagrange.lcc_decode(evals[: rthr - 1], alphas[: rthr - 1],
                                betas, k)
    expected = jnp.stack([_poly_f(blocks[i], w, coeffs) for i in range(k)])
    assert not np.array_equal(np.asarray(short), np.asarray(expected))


def test_coded_slices_uniform(rng):
    """With T >= 1 random masks, each coded slice marginal looks uniform."""
    k, t = 2, 1
    n = 8
    alphas, betas = lagrange.default_points(n, k, t)
    blocks = jnp.zeros((k, 16, 8), jnp.int32)        # all-zero data!
    vals = []
    for i in range(50):
        masks = F.random_field(jax.random.PRNGKey(i), (t, 16, 8))
        coded = lagrange.lcc_encode(blocks, masks, alphas, betas)
        vals.append(np.asarray(coded[0]).ravel())
    m = np.mean(np.concatenate(vals)) / F.P
    assert abs(m - 0.5) < 0.02   # uniform mean despite all-zero data
