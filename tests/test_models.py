"""Per-arch smoke tests (reduced configs): one train step + decode
consistency + no NaNs, as required for every assigned architecture."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import model_zoo as MZ
from repro.models.config import applicable_shapes
from repro.optim import optimizers

ARCHS = [a for a in registry.ARCH_IDS if a != "copml-logreg"]


def _batch(cfg, b=2, s=16):
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (b, s), 0, cfg.vocab),
        "labels": jax.random.randint(
            jax.random.PRNGKey(2), (b, s), 0, cfg.vocab),
        "mask": jnp.ones((b, s), jnp.float32)}
    fs = MZ._frontier_shape(cfg, b)
    if fs is not None:
        batch["frontier"] = jnp.full(fs, 0.01, cfg.jdtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = registry.smoke_config(arch)
    bm = MZ.build(cfg)
    params = bm.init_params(jax.random.PRNGKey(0))
    opt = optimizers.make(cfg.optimizer)
    opt_state = opt.init(params)
    batch = _batch(cfg)
    p2, o2, metrics = jax.jit(bm.train_step)(
        params, opt_state, batch, jnp.zeros((), jnp.int32))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually changed
    moved = any(not np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert moved


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_full_forward(arch):
    """Decode with a cache must reproduce the full-forward logits.

    MoE archs get a generous capacity factor: token-dropping differs
    between a 24-token pass and a 1-token pass BY DESIGN, and this test
    isolates cache correctness, not routing capacity."""
    cfg = registry.smoke_config(arch)
    if cfg.family == "moe":
        cfg = cfg.scaled(capacity_factor=8.0)
    bm = MZ.build(cfg)
    params = bm.init_params(jax.random.PRNGKey(0))
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(3), (b, s + 1), 0,
                                cfg.vocab)
    batch = {"tokens": tokens[:, :s]}
    fs = MZ._frontier_shape(cfg, b)
    if fs is not None:
        batch["frontier"] = jnp.full(fs, 0.01, cfg.jdtype)

    # full forward over s+1 tokens: logits at position s
    full_batch = dict(batch, tokens=tokens)
    full_logits, _ = jax.jit(bm.prefill_step)(params, full_batch)

    # prefill s tokens -> pad cache -> decode token s
    logits0, pcache = jax.jit(bm.prefill_step)(params, batch)
    # vlm caches hold the patch prefix too
    max_seq = s + 8 + (cfg.n_patches if cfg.family == "vlm" else 0)
    caches = MZ.init_cache(cfg, b, max_seq)
    from repro.models.lm_serving import _copy_prefill_into_cache
    caches = _copy_prefill_into_cache(cfg, pcache, caches, s)
    pos0 = s + (cfg.n_patches if cfg.family == "vlm" else 0)
    dec_logits, _ = jax.jit(bm.decode_step)(
        params, caches, tokens[:, s:s + 1], jnp.asarray(pos0, jnp.int32))

    a = np.asarray(full_logits[:, -1], np.float32)
    d = np.asarray(dec_logits[:, -1], np.float32)
    # bf16 compute: compare top-1 agreement + correlation
    corr = np.corrcoef(a.ravel(), d.ravel())[0, 1]
    assert corr > 0.98, f"{arch}: decode/forward mismatch corr={corr}"
    top_match = (a.argmax(-1) == d.argmax(-1)).mean()
    assert top_match >= 0.5, f"{arch}: top-1 agreement {top_match}"


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_close_to_nameplate(arch):
    """Full config's param count should be the right order of magnitude."""
    cfg = registry.get_config(arch)
    n = cfg.param_count()
    nameplate = {"qwen3-1.7b": 1.7e9, "qwen2.5-3b": 2.6e9,
                 "smollm-360m": 3.2e8, "llama3.2-3b": 3.0e9,
                 "falcon-mamba-7b": 7e9, "qwen3-moe-30b-a3b": 3.0e10,
                 "arctic-480b": 4.6e11, "whisper-tiny": 3.5e7,
                 "zamba2-2.7b": 2.4e9, "internvl2-2b": 2.0e9}[arch]
    assert nameplate / 3 < n < nameplate * 3, (arch, n, nameplate)


def test_long_context_applicability():
    subq = {a for a in ARCHS
            if applicable_shapes(registry.get_config(a))[-1].name
            == "long_500k"}
    assert subq == {"falcon-mamba-7b", "zamba2-2.7b"}


def test_loss_chunking_equivalent():
    cfg = registry.smoke_config("smollm-360m")
    params = MZ.build(cfg).init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg, b=2, s=32)
    l_full = MZ.build(cfg, loss_chunk=0).loss_fn(params, batch)[1]
    l_chunk = MZ.build(cfg, loss_chunk=8).loss_fn(params, batch)[1]
    np.testing.assert_allclose(float(l_full), float(l_chunk), rtol=2e-2)


def test_microbatch_equivalent():
    cfg = registry.smoke_config("smollm-360m")
    bm0 = MZ.build(cfg)
    bm4 = MZ.build(cfg, microbatch=2)
    params = bm0.init_params(jax.random.PRNGKey(0))
    opt = optimizers.make(cfg.optimizer)
    batch = _batch(cfg, b=4, s=16)
    _, _, m0 = jax.jit(bm0.train_step)(params, opt.init(params), batch,
                                       jnp.zeros((), jnp.int32))
    _, _, m4 = jax.jit(bm4.train_step)(params, opt.init(params), batch,
                                       jnp.zeros((), jnp.int32))
    np.testing.assert_allclose(float(m0["loss"]), float(m4["loss"]),
                               rtol=3e-2)


def test_mamba2_ssd_matches_scan():
    """The SSD chunked-matmul path must equal the explicit recurrence."""
    from repro.models import ssm
    cfg = registry.smoke_config("zamba2-2.7b")
    bm = MZ.build(cfg)
    params = bm.init_params(jax.random.PRNGKey(0))
    p = {k.split("/", 1)[1]: v[0] for k, v in params.items()
         if k.startswith("layers/")}
    x = (jax.random.normal(jax.random.PRNGKey(5), (2, 64, cfg.d_model))
         * 0.1).astype(cfg.jdtype)
    y_ssd, (_, h_ssd) = ssm.mamba2_forward(p, x, cfg, chunk=16)
    y_scan, (_, h_scan) = ssm.mamba2_forward_scan(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y_ssd, np.float32),
                               np.asarray(y_scan, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(h_ssd), np.asarray(h_scan),
                               atol=1e-3, rtol=1e-3)
