"""Distributed COPML engine: train_sharded bit-exact vs train_jit.

The real multi-device checks need XLA_FLAGS=--xla_force_host_platform_
device_count set BEFORE jax initializes, which the in-process suite must
not do (tests/conftest.py keeps the host's real device view), so they run
in ONE fresh subprocess covering 4- and 8-device meshes, ragged and
divisible client counts, case1/case2 parameterizations, straggler subsets,
and the dryrun_cell smoke.  A 1-device-mesh parity test exercises the
shard_map code path in-process on every host.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.core import meshutil
from repro.core.protocol import Copml, CopmlConfig, case1_params, case2_params
from repro.data import pipeline

assert len(jax.devices()) == 8, jax.devices()


def parity(tag, n, k, t, ndev, subset=None, history=False, iters=3,
           objective=None):
    if objective is not None and objective.dataset_kind == "multiclass":
        x, y = pipeline.multiclass_dataset(m=78, d=6,
                                           n_classes=objective.n_outputs,
                                           seed=3)
    else:
        x, y = pipeline.classification_dataset(m=78, d=6, seed=3, margin=2.0)
    cfg = CopmlConfig(n_clients=n, k=k, t=t, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1], objective=objective)
    cx, cy = pipeline.split_clients(x, y, n)
    key = jax.random.PRNGKey(5)
    mesh = meshutil.client_mesh(ndev)
    if history:
        st_j, w_j, h_j = proto.train_jit(key, cx, cy, iters, subset=subset,
                                         history=True)
        st_s, w_s, h_s = proto.train_sharded(key, cx, cy, iters, mesh=mesh,
                                             subset=subset, history=True)
        np.testing.assert_array_equal(np.asarray(h_j), np.asarray(h_s))
    else:
        st_j, w_j = proto.train_jit(key, cx, cy, iters, subset=subset)
        st_s, w_s = proto.train_sharded(key, cx, cy, iters, mesh=mesh,
                                        subset=subset)
    np.testing.assert_array_equal(np.asarray(w_j), np.asarray(w_s))
    np.testing.assert_array_equal(np.asarray(st_j.w_shares),
                                  np.asarray(st_s.w_shares))
    assert int(st_s.step) == iters
    print("PARITY", tag, flush=True)


# ragged: 13 clients on 4 devices (case1, K=4 T=1), with per-step history
k1, t1 = case1_params(13)
parity("case1_n13_dev4_history", 13, k1, t1, 4, history=True)
# ragged: 13 clients on 8 devices
parity("case1_n13_dev8", 13, k1, t1, 8)
# divisible: 16 clients, case2 (T=2) on 8 devices
k2, t2 = case2_params(16)
assert t2 == 2
parity("case2_n16_dev8", 16, k2, t2, 8)
# straggler subset: decode from the LAST R of N clients
parity("subset_n13_dev4", 13, 3, 1, 4, subset=tuple(range(3, 13)))

# multi-class (d, C) matrix model over REAL collectives: the class-batched
# encode/exchange/decode path is bit-exact vs the single-device jit engine
from repro.core import objectives
parity("ovr3_n13_dev4_history", 13, 3, 1, 4, history=True,
       objective=objectives.multiclass_logistic(3))

# FaultPlan replayed over REAL collectives: per-step churn threaded through
# the shard_map scan, bit-exact vs the single-device jit engine
from repro import api
plan = api.FaultPlan.random(13, 3, seed=2, straggle_p=0.3, min_available=10)
assert not plan.is_fault_free
wl = api.Workload(name="dist_faults", m=78, d=6, seed=3,
                  cfg=CopmlConfig(n_clients=13, k=3, t=1, eta=1.0), iters=3)
res_s = api.fit(wl, "copml",
                api.EngineSpec("sharded", mesh=meshutil.client_mesh(4)),
                key=5, iters=3, faults=plan, history=True)
res_j = api.fit(wl, "copml", "jit", key=5, iters=3, faults=plan,
                history=True)
np.testing.assert_array_equal(res_s.weights, res_j.weights)
np.testing.assert_array_equal(np.asarray(res_s.history),
                              np.asarray(res_j.history))
np.testing.assert_array_equal(res_s.availability, plan.available)
print("PARITY faultplan_n13_dev4", flush=True)

# the same churn schedule on the MULTI-CLASS path: sharded == jit == the
# fault-free run (decode invariance holds columnwise on the matrix model)
wl_mc = api.Workload(name="dist_faults_ovr3", m=78, d=6, seed=3,
                     cfg=CopmlConfig(n_clients=13, k=3, t=1, eta=1.0),
                     iters=3, objective=objectives.multiclass_logistic(3))
res_ms = api.fit(wl_mc, "copml",
                 api.EngineSpec("sharded", mesh=meshutil.client_mesh(4)),
                 key=5, iters=3, faults=plan, history=True)
res_mj = api.fit(wl_mc, "copml", "jit", key=5, iters=3, faults=plan,
                 history=True)
res_m0 = api.fit(wl_mc, "copml", "jit", key=5, iters=3, history=True)
np.testing.assert_array_equal(res_ms.weights, res_mj.weights)
np.testing.assert_array_equal(np.asarray(res_ms.history),
                              np.asarray(res_mj.history))
np.testing.assert_array_equal(res_mj.weights, res_m0.weights)
assert res_mj.weights.shape == (6, 3)
print("PARITY faultplan_ovr3_n13_dev4", flush=True)

# dryrun_cell smoke: compile one real sharded iteration, check collectives.
# Default (overlap on): the ENC reduce-scatter and SHARE all-to-all lower to
# ppermute rings, so the HLO carries collective-permutes plus the OPEN
# all-gather; REPRO_SHARDED_OVERLAP=0 restores the monolithic collectives.
from repro.launch import copml_dist
rec = copml_dist.dryrun_cell("smoke", meshutil.client_mesh(4), False)
assert rec["status"] == "ok", rec
assert rec["n_clients"] == 4
colls = rec["collectives"]
assert colls["collective-permute"] >= 2 and colls["all-gather"] >= 1, colls
os.environ["REPRO_SHARDED_OVERLAP"] = "0"
colls0 = copml_dist.dryrun_cell(
    "smoke", meshutil.client_mesh(4), False)["collectives"]
del os.environ["REPRO_SHARDED_OVERLAP"]
assert colls0["all-to-all"] >= 1 and colls0["reduce-scatter"] >= 1 \
    and colls0["all-gather"] >= 1, colls0
assert "skipped" in copml_dist.dryrun_cell(
    "long_500k", meshutil.client_mesh(4), False)["status"]
print("DRYRUN OK", flush=True)
print("ALL OK", flush=True)
"""


@pytest.mark.slow
def test_train_sharded_bit_exact_subprocess():
    """4/8 virtual devices: sharded == train_jit bit-for-bit (see module
    docstring for the matrix), plus the dryrun_cell smoke."""
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, env=env, cwd=_REPO,
                         timeout=1500)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    for marker in ("PARITY case1_n13_dev4_history", "PARITY case1_n13_dev8",
                   "PARITY case2_n16_dev8", "PARITY subset_n13_dev4",
                   "PARITY ovr3_n13_dev4_history",
                   "PARITY faultplan_n13_dev4",
                   "PARITY faultplan_ovr3_n13_dev4", "DRYRUN OK", "ALL OK"):
        assert marker in out.stdout, (marker, out.stdout[-2000:])


def test_train_sharded_single_device_mesh():
    """The shard_map engine on a trivial 1-device mesh (no XLA flags
    needed): same collective program structure, bit-exact vs train_jit."""
    import jax

    from repro.core import meshutil
    from repro.core.protocol import Copml, CopmlConfig, case1_params
    from repro.data import pipeline

    x, y = pipeline.classification_dataset(m=70, d=6, seed=4, margin=2.0)
    n = 7
    cfg = CopmlConfig(n_clients=n, k=2, t=1, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1])
    cx, cy = pipeline.split_clients(x, y, n)
    key = jax.random.PRNGKey(11)
    st_j, w_j = proto.train_jit(key, cx, cy, iters=3)
    st_s, w_s = proto.train_sharded(key, cx, cy, iters=3,
                                    mesh=meshutil.client_mesh(1))
    np.testing.assert_array_equal(np.asarray(w_j), np.asarray(w_s))
    np.testing.assert_array_equal(np.asarray(st_j.w_shares),
                                  np.asarray(st_s.w_shares))


def test_client_mesh_and_padding_helpers():
    from repro.core import meshutil
    from repro.core.protocol import _pad_clients
    import jax.numpy as jnp

    mesh = meshutil.client_mesh(1)
    assert tuple(mesh.axis_names) == (meshutil.CLIENT_AXIS,)
    a = jnp.arange(6, dtype=jnp.int32).reshape(3, 2)
    p = _pad_clients(a, 4)
    assert p.shape == (4, 2)
    np.testing.assert_array_equal(np.asarray(p[3]), np.zeros(2))
    assert _pad_clients(a, 3) is a
