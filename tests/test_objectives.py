"""SecureObjective layer: registry, target embedding, and the core
property -- the kernel-path field gradient equals an independent
integer-oracle evaluation of the quantized reference gradient, for RANDOM
objectives and shapes (hypothesis / deterministic shim)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.core import objectives
from repro.core.protocol import CopmlConfig
from repro.kernels import ops, ref

MAX_SEED = 2 ** 31 - 1


# ------------------------------------------------------------- registry


def test_registry_and_shapes():
    assert set(objectives.names()) >= {"logistic", "linreg", "ovr10"}
    obj = objectives.get("ovr10")
    assert obj.n_outputs == 10 and obj.out_shape == (10,)
    assert obj.w_shape(24) == (24, 10)
    assert objectives.BINARY_LOGISTIC.w_shape(12) == (12,)
    assert objectives.LINREG.out_shape == ()
    with pytest.raises(KeyError, match="unknown objective"):
        objectives.get("softmax")
    # ad-hoc class counts need not be registered
    assert objectives.multiclass_logistic(3).name == "ovr3"
    with pytest.raises(ValueError, match="n_classes >= 2"):
        objectives.multiclass_logistic(1)
    # instances are hashable + value-equal (Workload caching keys on them)
    assert objectives.multiclass_logistic(3) == objectives.multiclass_logistic(3)
    assert hash(objectives.BinaryLogistic()) == hash(objectives.BINARY_LOGISTIC)


def test_linreg_requires_degree_one():
    cfg = CopmlConfig(n_clients=13, k=2, t=1, r=2)
    with pytest.raises(ValueError, match="degree 1"):
        objectives.LINREG.validate_cfg(cfg)
    cfg1 = CopmlConfig(n_clients=13, k=4, t=1, r=1)
    coeffs = objectives.LINREG.field_coeffs(cfg1)
    # ghat(z) = z quantizes EXACTLY: c0 = 0, c1 = 2^cb
    np.testing.assert_array_equal(coeffs, [0, 1 << cfg1.cb])


def test_binary_field_coeffs_match_preobjective_quantization():
    """The logistic objective reproduces sigmoid_approx.quantized_coeffs
    byte for byte -- the guarantee behind the seed goldens."""
    from repro.core import sigmoid_approx
    cfg = CopmlConfig(n_clients=13, k=4, t=1)
    scales = [cfg.lg - i * cfg.lz for i in range(cfg.r + 1)]
    expect = sigmoid_approx.quantized_coeffs(cfg.r, cfg.lx, scales,
                                             cfg.sigmoid_bound)
    np.testing.assert_array_equal(
        objectives.BINARY_LOGISTIC.field_coeffs(cfg), expect)


def test_prepare_targets():
    ovr = objectives.multiclass_logistic(4)
    one_hot = ovr.prepare_targets(np.array([0, 3, 1]))
    np.testing.assert_array_equal(
        one_hot, [[1, 0, 0, 0], [0, 0, 0, 1], [0, 1, 0, 0]])
    assert one_hot.dtype == np.float32
    with pytest.raises(ValueError, match="class labels"):
        ovr.prepare_targets(np.array([0, 4]))
    with pytest.raises(ValueError, match="class labels"):
        ovr.prepare_targets(np.array([[0, 1]]))
    y = np.array([0.0, 1.0, 1.0], np.float32)
    np.testing.assert_array_equal(
        objectives.BINARY_LOGISTIC.prepare_targets(y), y)


def test_scores():
    # multiclass: argmax accuracy + per-class recall (NaN when absent)
    ovr = objectives.multiclass_logistic(3)
    x = np.eye(3)
    w = np.eye(3) * 5.0                   # predicts class i for e_i
    y = np.array([0, 1, 0])               # row 2 (e_2) mispredicted as 2
    assert ovr.score(w, x, y) == pytest.approx(2 / 3)
    pca = ovr.per_class_accuracy(w, x, y)
    assert pca[0] == pytest.approx(0.5) and pca[1] == 1.0
    assert np.isnan(pca[2])
    # linreg: R^2 = 1 for a perfect fit, < 1 otherwise
    rng = np.random.default_rng(0)
    xr = rng.normal(size=(20, 3))
    wr = rng.normal(size=3)
    assert objectives.LINREG.score(wr, xr, xr @ wr) == pytest.approx(1.0)
    assert objectives.LINREG.score(wr * 0, xr, xr @ wr) <= 0.0 + 1e-9


# ----------------------------------------- the gradient-equality property


def _int_oracle_gradient(xq, wq, coeffs):
    """Independent numpy-uint64 evaluation of X^T ghat(X W) mod p (the
    quantized reference gradient): field.np_matmul + Horner, no jnp."""
    z = F.np_matmul(np.asarray(xq), np.asarray(wq))           # (m, C')
    g = np.full_like(z, int(coeffs[-1]))
    for ci in range(len(coeffs) - 2, -1, -1):
        g = (F.np_mul(g, z) + int(coeffs[ci])) % F.P
    return F.np_matmul(np.asarray(xq).T, g)                   # (d, C')


def _quantize_np(x, scale):
    q = np.round(np.asarray(x, np.float64) * (1 << scale)).astype(np.int64)
    return (q % F.P).astype(np.int32)


@given(st.integers(0, MAX_SEED),
       st.sampled_from(["logistic", "logistic_r2", "linreg", "ovr2", "ovr3"]))
@settings(max_examples=8, deadline=None)
def test_field_gradient_equals_quantized_reference(seed, obj_name):
    """The kernels-path coded gradient (what Phase 3 runs) is EXACTLY the
    integer-oracle evaluation of the objective's quantized gradient
    polynomial, for random objectives, degrees, and shapes."""
    rng = np.random.default_rng(seed)
    r = 2 if obj_name == "logistic_r2" else 1
    obj = {"logistic": objectives.BINARY_LOGISTIC,
           "logistic_r2": objectives.BINARY_LOGISTIC,
           "linreg": objectives.LINREG,
           "ovr2": objectives.multiclass_logistic(2),
           "ovr3": objectives.multiclass_logistic(3)}[obj_name]
    n_req = (2 * r + 1) * 2 + 1
    cfg = CopmlConfig(n_clients=max(7, n_req), k=2, t=1, r=r)
    obj.validate_cfg(cfg)
    coeffs = obj.field_coeffs(cfg)

    m = int(rng.integers(4, 12))
    d = int(rng.integers(2, 6))
    nb = int(rng.integers(1, 4))          # client batch
    xq = _quantize_np(rng.uniform(-1, 1, size=(nb, m, d)), cfg.lx)
    wq = _quantize_np(rng.uniform(-2, 2, size=(nb,) + obj.w_shape(d)),
                      cfg.lw)

    if obj.out_shape:
        got = np.asarray(ops.coded_gradient_matrix(
            jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(coeffs)))
        oracle = np.stack([_int_oracle_gradient(xq[i], wq[i], coeffs)
                           for i in range(nb)])
    else:
        got = np.asarray(ops.coded_gradient_batched(
            jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(coeffs)))
        oracle = np.stack([
            _int_oracle_gradient(xq[i], wq[i][:, None], coeffs)[:, 0]
            for i in range(nb)])
    np.testing.assert_array_equal(got, oracle.astype(np.int64))


def test_matrix_gradient_columns_equal_vector_gradients():
    """Class batching is pure batching: column c of the matrix coded
    gradient equals the vector coded gradient of w[:, c]."""
    rng = np.random.default_rng(3)
    cfg = CopmlConfig(n_clients=13, k=4, t=1)
    obj = objectives.multiclass_logistic(4)
    coeffs = obj.field_coeffs(cfg)
    xq = jnp.asarray(_quantize_np(rng.uniform(-1, 1, (2, 9, 5)), cfg.lx))
    wq = jnp.asarray(_quantize_np(rng.uniform(-2, 2, (2, 5, 4)), cfg.lw))
    full = np.asarray(ops.coded_gradient_matrix(xq, wq, jnp.asarray(coeffs)))
    for c in range(4):
        col = np.asarray(ops.coded_gradient_batched(
            xq, wq[:, :, c], jnp.asarray(coeffs)))
        np.testing.assert_array_equal(full[:, :, c], col)


def test_matrix_pallas_kernel_matches_reference():
    """The class-batched Pallas kernel (interpret mode on CPU) agrees with
    the jnp reference elementwise mod p."""
    rng = np.random.default_rng(5)
    xq = jnp.asarray(rng.integers(0, F.P, size=(2, 16, 8)).astype(np.int32))
    wq = jnp.asarray(rng.integers(0, F.P, size=(2, 8, 3)).astype(np.int32))
    coeffs = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    want = np.asarray(ref.coded_gradient_matrix(xq, wq, coeffs))
    got = np.asarray(ops.coded_gradient_matrix(xq, wq, coeffs,
                                               bm=8, dc=8,
                                               force_pallas=True))
    np.testing.assert_array_equal(got, want)


def test_dequantized_gradient_tracks_float_reference():
    """Dequantizing the field gradient at scale lx+lg recovers the float
    polynomial gradient up to coefficient rounding (|err| bounded by the
    ghat coefficient grid x the z range x m rows)."""
    rng = np.random.default_rng(11)
    cfg = CopmlConfig(n_clients=13, k=4, t=1)
    obj = objectives.BINARY_LOGISTIC
    m, d = 16, 4
    x = rng.uniform(-1, 1, size=(m, d))
    w = np.round(rng.uniform(-2, 2, size=d) * (1 << cfg.lw)) / (1 << cfg.lw)
    xg = np.round(x * (1 << cfg.lx)) / (1 << cfg.lx)   # the grids the
    #                                                    field path sees
    xq = _quantize_np(xg, cfg.lx)
    wq = _quantize_np(w, cfg.lw)
    f = _int_oracle_gradient(xq, wq[:, None], obj.field_coeffs(cfg))[:, 0]
    signed = np.where(f > F.P // 2, f - F.P, f)
    got = signed / float(1 << (cfg.lx + cfg.lg))
    cs = obj.float_coeffs(cfg.r, cfg.sigmoid_bound)
    ghat = np.zeros(m)
    for c in reversed(cs):
        ghat = ghat * (xg @ w) + c
    want = xg.T @ ghat
    # error budget: c1 rounds on the 2^-cb grid, |z| <= d*2 per row
    tol = m * (0.5 ** cfg.cb) * (d * 2) + m * 2.0 ** -(cfg.lg + 1) + 1e-9
    np.testing.assert_allclose(got, want, atol=tol)
