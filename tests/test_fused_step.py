"""Fused megakernel conformance (kernels/fused_step.py + ops wiring).

Property-based (real `hypothesis` or the deterministic shim): the
one-dispatch fused Phase-3/4 step is bit-exact vs the phase-by-phase
reference over random shapes/degrees/class widths; Barrett reduction and
the grouped-limb matmul agree with plain `% P` arithmetic over the full
reachable range.  Plus the tuned-block selection contract and the
protocol-level golden: REPRO_FUSED_STEP=kernel (forced Pallas megakernel)
reproduces the pre-refactor smoke-workload share hash bit-for-bit.
"""

import hashlib

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.kernels import ops, ref

MAX_SEED = 2 ** 31 - 1
K1 = 8

# smoke workload golden (tests/test_api.py): key=PRNGKey(0), 10 iterations
GOLDEN_SHARES_SHA = \
    "459aaa671b3d6708b4918f1e54b29e083cecf6c85b5b617f882720596399afaf"


def _operands(rng, n, m, d, c, degree):
    def fld(*s):
        return jnp.asarray(
            rng.integers(0, F.P, size=s, dtype=np.int64).astype(np.int32))
    return (fld(n, m, d), fld(n, d, c), fld(degree + 1), fld(n), fld(n),
            fld(n), fld(n, d, c), fld(n, d, c), fld(n, d, c), fld(n, d, c),
            fld(n, d, c))


@given(st.integers(0, MAX_SEED), st.integers(1, 3),
       st.sampled_from([1, 3, 10]))
@settings(max_examples=8, deadline=None)
def test_fused_step_matches_phase_reference(seed, degree, c):
    """ops.fused_step(force_pallas) == ref.fused_step over random client
    counts, ragged sample/feature dims, gradient degrees, and C."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 5))
    m = int(rng.integers(5, 40))
    d = int(rng.integers(3, 25))
    args = _operands(rng, n, m, d, c, degree)
    kw = dict(q_eta=int(rng.integers(1, F.P)), inv2k1=F.host_inv(1 << K1),
              k1=K1)
    f_ref, w_ref = ref.fused_step(*args, **kw)
    f_k, w_k = ops.fused_step(*args, bm=8, dc=8, force_pallas=True, **kw)
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_ref))
    np.testing.assert_array_equal(np.asarray(w_k), np.asarray(w_ref))


@given(st.integers(0, MAX_SEED))
@settings(max_examples=8, deadline=None)
def test_barrett_reduce_equals_mod_p(seed):
    """barrett_reduce == `% P` over the whole admissible range [0, 2^31):
    boundary values pinned, the rest drawn uniformly."""
    rng = np.random.default_rng(seed)
    t = rng.integers(0, 2 ** 31, size=(4096,), dtype=np.int64)
    t[:6] = (0, 1, F.P - 1, F.P, 2 * F.P - 1, 2 ** 31 - 1)
    got = np.asarray(F.barrett_reduce(jnp.asarray(t.astype(np.int32))))
    np.testing.assert_array_equal(got, (t % F.P).astype(np.int32))


@given(st.integers(0, MAX_SEED), st.sampled_from([1, 16, 127, 1024]))
@settings(max_examples=8, deadline=None)
def test_grouped_limb_matmul_equals_int64_mod(seed, k):
    """The grouped-weight + one-Barrett-reduce contraction (jnp AND the
    Pallas modmatmul kernel) matches plain int64 `% P` up to the
    documented contraction bound k <= 1024."""
    rng = np.random.default_rng(seed)
    a = rng.integers(0, F.P, size=(5, k), dtype=np.int64)
    b = rng.integers(0, F.P, size=(k, 3), dtype=np.int64)
    want = ((a @ b) % F.P).astype(np.int32)   # < 1024 * p^2 < 2^63: exact
    aj = jnp.asarray(a.astype(np.int32))
    bj = jnp.asarray(b.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(F.matmul(aj, bj)), want)
    np.testing.assert_array_equal(
        np.asarray(ops.modmatmul(aj, bj, force_pallas=True)), want)


# ------------------------------------------------------- block selection


def test_pick_blocks_env_override(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_BLOCKS", "64,32")
    assert ops.pick_blocks(390, 24, 10) == (64, 32)


def test_pick_blocks_table_and_fallback(monkeypatch):
    """Bucketed table hit wins; unknown buckets derive minima from the
    ACTUAL shape (the ragged matrix path shrinks dc when C is wide)."""
    assert ops.block_key(390, 24, 10) == "m512_d32_c16"
    monkeypatch.delenv("REPRO_PALLAS_BLOCKS", raising=False)
    monkeypatch.setattr(ops, "_block_table_cache",
                        {"m512_d32_c16": {"bm": 256, "dc": 16}})
    assert ops.pick_blocks(390, 24, 10) == (256, 16)
    # fallback: no entry for this bucket; bm clamps to bucket(13) == 16
    # and dc halves while dc * bucket(C) exceeds the VMEM budget
    assert ops.pick_blocks(13, 512, 300) == (16, 32)


def test_coded_gradient_matrix_ragged_regression():
    """(m=13, C=10): the matrix path's blocks derive from the real shape
    (pre-fix the vector-path minima padded this shape pathologically)."""
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.integers(0, F.P, size=(3, 13, 6),
                                 dtype=np.int64).astype(np.int32))
    w = jnp.asarray(rng.integers(0, F.P, size=(3, 6, 10),
                                 dtype=np.int64).astype(np.int32))
    coeffs = jnp.asarray(rng.integers(0, F.P, size=(2,),
                                      dtype=np.int64).astype(np.int32))
    got = ops.coded_gradient_matrix(x, w, coeffs, force_pallas=True)
    want = ref.coded_gradient_matrix(x, w, coeffs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- protocol golden


def test_forced_kernel_golden_shares(monkeypatch):
    """REPRO_FUSED_STEP=kernel (the Pallas megakernel inside the jit scan)
    reproduces the pre-refactor smoke-workload share hash bit-for-bit."""
    from repro import api
    monkeypatch.setenv("REPRO_FUSED_STEP", "kernel")
    res = api.fit("smoke", "copml", "jit", key=0, iters=10, history=False)
    sha = hashlib.sha256(
        np.asarray(res.state.w_shares, np.int32).tobytes()).hexdigest()
    assert sha == GOLDEN_SHARES_SHA
