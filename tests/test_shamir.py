"""Shamir sharing: reconstruction from any T+1 shares, resharing, privacy."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field as F, shamir


@pytest.mark.parametrize("t,n", [(1, 4), (2, 7), (3, 9)])
def test_share_reconstruct_all_subsets(rng, t, n):
    secret = jnp.asarray(rng.integers(0, F.P, size=(3, 5)).astype(np.int32))
    shares = shamir.share(jax.random.PRNGKey(0), secret, t, n)
    assert shares.shape == (n, 3, 5)
    for subset in itertools.islice(
            itertools.combinations(range(n), t + 1), 12):
        rec = shamir.reconstruct(shares, t, subset=subset)
        np.testing.assert_array_equal(np.asarray(rec), np.asarray(secret))


def test_t_shares_leak_nothing_statistically(rng):
    """Any T shares of two different secrets are identically distributed --
    here tested via matching first/second moments over many sharings."""
    t, n, trials = 2, 6, 300
    s0 = jnp.zeros((4,), jnp.int32)
    s1 = jnp.full((4,), F.P - 1, jnp.int32)
    obs = {0: [], 1: []}
    for i in range(trials):
        k = jax.random.PRNGKey(i)
        obs[0].append(np.asarray(shamir.share(k, s0, t, n)[:t]))
        obs[1].append(np.asarray(shamir.share(k, s1, t, n)[:t]))
    m0 = np.mean(obs[0]) / F.P
    m1 = np.mean(obs[1]) / F.P
    # both should look uniform on [0, p): mean ~ 0.5
    assert abs(m0 - 0.5) < 0.05 and abs(m1 - 0.5) < 0.05


def test_linear_ops_on_shares(rng):
    """add / mul-by-const commute with reconstruction (local MPC ops)."""
    t, n = 2, 7
    a = jnp.asarray(rng.integers(0, F.P, size=(8,)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(8,)).astype(np.int32))
    sa = shamir.share(jax.random.PRNGKey(0), a, t, n)
    sb = shamir.share(jax.random.PRNGKey(1), b, t, n)
    got = shamir.reconstruct(F.add(sa, sb), t)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(F.add(a, b)))
    got = shamir.reconstruct(F.mul_scalar(sa, 12345), t)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(F.mul_scalar(a, 12345)))


def test_reshare_degree_reduction(rng):
    """Local product of shares lies on a degree-2T polynomial; resharing
    brings it back to degree T while preserving the secret product."""
    t, n = 1, 5
    a = jnp.asarray(rng.integers(0, F.P, size=(6,)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(6,)).astype(np.int32))
    sa = shamir.share(jax.random.PRNGKey(0), a, t, n)
    sb = shamir.share(jax.random.PRNGKey(1), b, t, n)
    prod_shares = F.mul(sa, sb)                      # degree 2T
    red = shamir.reshare(jax.random.PRNGKey(2), prod_shares, t, n)
    got = shamir.reconstruct(red, t)                 # T+1 shares suffice now
    np.testing.assert_array_equal(np.asarray(got), np.asarray(F.mul(a, b)))
