"""train/elastic.py budgets + train/checkpoint.py re-mesh restore.

The elastic module's budget math is the validation layer of the fault
engine (tests/test_faults.py covers that wiring); here the primitives
get direct coverage: budget arithmetic, mesh re-planning on awkward
(non-power-of-two) device counts, and checkpoint save -> restore parity
when the restore lands on a re-planned mesh.
"""

import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.train import checkpoint, elastic

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------------ budgets


def test_straggler_budget_matches_paper_threshold():
    # N=50, Case 2 (K=10, T=7): R = 3*(10+7-1)+1 = 49 -> 1 client of slack
    b = elastic.straggler_budget(50, 10, 7)
    assert (b.recovery_threshold, b.tolerable) == (49, 1)
    # r scales the polynomial degree: r=2 -> (2r+1)=5
    assert elastic.straggler_budget(40, 4, 2, r=2).recovery_threshold == 26
    # smoke_straggler's shape
    assert elastic.straggler_budget(13, 3, 1).tolerable == 3


def test_secure_agg_budget():
    b = elastic.secure_agg_budget(13, 2)
    assert (b.n, b.recovery_threshold, b.tolerable) == (13, 3, 10)


def test_plan_headroom_and_validate():
    np.testing.assert_array_equal(
        elastic.plan_headroom([12, 10, 13], 10), [2, 0, 3])
    elastic.validate_budget([12, 10, 13], 10)          # no raise
    with pytest.raises(elastic.FaultPlanViolation,
                       match="step 1.*threshold 10"):
        elastic.validate_budget([12, 9, 8], 10, "COPML decode")


# ------------------------------------------------------------------ replan


def test_replan_shape_non_power_of_two_counts():
    """The factorization behind replan_mesh: model picks the largest
    power-of-two divisor of the device count <= prefer_model."""
    assert elastic.replan_shape(6) == (3, 2)
    assert elastic.replan_shape(12) == (3, 4)
    assert elastic.replan_shape(48) == (3, 16)
    assert elastic.replan_shape(7) == (7, 1)       # odd: model collapses
    assert elastic.replan_shape(1) == (1, 1)
    assert elastic.replan_shape(8, prefer_model=4) == (2, 4)
    for n in (1, 2, 3, 5, 6, 7, 12, 24, 40, 96):
        data, model = elastic.replan_shape(n)
        assert data * model == n and model & (model - 1) == 0


def test_replan_mesh_single_device():
    mesh = elastic.replan_mesh(1)
    assert tuple(mesh.axis_names) == ("data", "model")
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# --------------------------------------------------------------- checkpoint


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((8, 4)).astype(np.float32),
        "opt": {"mu": rng.standard_normal((8, 4)).astype(np.float32)},
        "step": 7,
    }


def test_checkpoint_roundtrip_and_newest_step(tmp_path):
    ck = checkpoint.Checkpointer(str(tmp_path), keep=2)
    t1, t2 = _tree(1), _tree(2)
    ck.save(1, t1, blocking=True)
    ck.save(2, t2, blocking=True)
    assert ck.list_steps() == [1, 2]
    restored, step = ck.restore(_tree(0))           # newest complete step
    assert step == 2 and restored["step"] == 7
    np.testing.assert_array_equal(restored["w"], t2["w"])
    np.testing.assert_array_equal(restored["opt"]["mu"], t2["opt"]["mu"])
    # keep=2 GC: a third save evicts step 1
    ck.save(3, _tree(3), blocking=True)
    assert ck.list_steps() == [2, 3]


def test_checkpoint_restore_onto_replanned_mesh(tmp_path):
    """save -> restore with shardings from a re-planned mesh: the elastic
    restart path (device_put against the NEW mesh's shardings).  On this
    host the re-planned mesh is (1, 1); the multi-device re-mesh runs in
    the subprocess test below."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    ck = checkpoint.Checkpointer(str(tmp_path))
    tree = _tree(4)
    ck.save(5, tree, blocking=True)
    mesh = elastic.replan_mesh(len(jax.devices()))
    sh = NamedSharding(mesh, P("data"))
    shardings = {"w": sh, "opt": {"mu": sh}, "step": None}
    restored, step = ck.restore(_tree(0), shardings=shardings)
    assert step == 5
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    np.testing.assert_array_equal(np.asarray(restored["opt"]["mu"]),
                                  tree["opt"]["mu"])
    assert restored["step"] == 7                  # scalar leaf cast
    assert restored["w"].sharding.is_equivalent_to(sh, restored["w"].ndim)


_SUBPROC = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train import checkpoint, elastic

assert len(jax.devices()) == 6
mesh = elastic.replan_mesh(6)                 # non-power-of-two: (3, 2)
assert dict(mesh.shape) == {"data": 3, "model": 2}, mesh.shape

# save sharded over (3, 2); restore re-planned onto a 1x2 slice "failure"
ck = checkpoint.Checkpointer("ckpt_remesh")
w = np.arange(24, dtype=np.float32).reshape(6, 4)
placed = jax.device_put(w, NamedSharding(mesh, P("data", "model")))
ck.save(1, {"w": placed}, blocking=True)

mesh2 = elastic.replan_mesh(6, prefer_model=1)    # (6, 1): all-data remesh
assert dict(mesh2.shape) == {"data": 6, "model": 1}
sh2 = NamedSharding(mesh2, P("data"))
restored, step = ck.restore({"w": w}, shardings={"w": sh2})
np.testing.assert_array_equal(np.asarray(restored["w"]), w)
assert restored["w"].sharding.is_equivalent_to(sh2, 2)
print("REMESH OK", flush=True)
"""


def test_replan_mesh_and_checkpoint_remesh_subprocess(tmp_path):
    """Non-power-of-two device count (6 virtual devices) end to end:
    replan_mesh factorization + checkpoint restore across two different
    re-planned meshes.  Needs XLA_FLAGS before jax imports, hence the
    subprocess; it only builds meshes and moves one tiny array."""
    env = dict(os.environ, PYTHONPATH=os.path.join(_REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SUBPROC],
                         capture_output=True, text=True, env=env,
                         cwd=str(tmp_path), timeout=300)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "REMESH OK" in out.stdout
