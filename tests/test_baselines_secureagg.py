"""MPC baselines (accuracy parity with COPML) + coded secure aggregation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import secure_agg as sa
from repro.core.baselines import MpcBaseline, float_logreg, sigmoid
from repro.core.cost_model import WanParams, Workload, copml_costs, \
    mpc_baseline_costs, speedup
from repro.core.protocol import CopmlConfig, case2_params
from repro.data import pipeline


def _acc(x, y, w):
    return float(((sigmoid(x @ np.asarray(w, np.float64)) > .5) == y).mean())


@pytest.mark.parametrize("scheme", ["bh08"])
def test_mpc_baseline_parity(scheme):
    x, y = pipeline.classification_dataset(m=204, d=10, seed=2, margin=2.0)
    n = 15
    k, t = case2_params(n)
    cfg = CopmlConfig(n_clients=n, k=k, t=t, eta=1.0)
    mb = MpcBaseline(cfg, x.shape[0], x.shape[1], groups=3, scheme=scheme)
    _, w = mb.train(jax.random.PRNGKey(0), x, y, 25)
    wf = float_logreg(x, y, 1.0, 25)
    assert _acc(x, y, w) > _acc(x, y, wf) - 0.08


def test_secure_agg_mean_close(rng):
    cfg = sa.SecureAggConfig(n_clients=6, t=2, lq=14, clip=4.0)
    grads = [{"w": jnp.asarray(rng.normal(size=(17, 3)).astype(np.float32)
                               * 0.2)} for _ in range(6)]
    mean = sa.secure_aggregate(jax.random.PRNGKey(0), grads, cfg)
    true = np.mean([np.asarray(g["w"]) for g in grads], axis=0)
    np.testing.assert_allclose(np.asarray(mean["w"]), true, atol=2 ** -12)


def test_secure_agg_straggler_subset(rng):
    """Reconstruction from the LAST T+1 holders matches the first T+1."""
    cfg = sa.SecureAggConfig(n_clients=7, t=2, lq=12, clip=2.0)
    grads = [{"w": jnp.asarray(rng.normal(size=(9,)).astype(np.float32)
                               * 0.1)} for _ in range(7)]
    m1 = sa.secure_aggregate(jax.random.PRNGKey(3), grads, cfg,
                             subset=(0, 1, 2))
    m2 = sa.secure_aggregate(jax.random.PRNGKey(3), grads, cfg,
                             subset=(4, 5, 6))
    np.testing.assert_allclose(np.asarray(m1["w"]), np.asarray(m2["w"]),
                               atol=1e-6)


def test_secure_agg_unbiased(rng):
    """Stochastic rounding in decode_mean: E[secure mean] == true mean."""
    cfg = sa.SecureAggConfig(n_clients=4, t=1, lq=6, clip=2.0)
    grads = [{"w": jnp.asarray(np.full(5, 0.013 * (j + 1), np.float32))}
             for j in range(4)]
    true = np.mean([np.asarray(g["w"]) for g in grads], axis=0)
    outs = [np.asarray(sa.secure_aggregate(jax.random.PRNGKey(i), grads,
                                           cfg)["w"]) for i in range(150)]
    np.testing.assert_allclose(np.mean(outs, axis=0), true, atol=3e-3)


def test_cost_model_reproduces_fig3_magnitudes():
    """Fig 3 headline: up to 8.6x (CIFAR-10) / 16.4x (GISETTE) over [BH08];
    our calibrated model lands in the same band at every N."""
    hw = WanParams()
    for n in (10, 26, 50):
        k, t = case2_params(n)
        w = Workload(m=6000, d=5000, n=n, k=k, t=t, iters=50)
        s = speedup(w, hw, scheme="bh08")
        assert 5.0 < s < 60.0, (n, s)
    # BGW is the slower baseline everywhere (paper Table I)
    k, t = case2_params(50)
    w = Workload(m=9019, d=3073, n=50, k=k, t=t, iters=50)
    assert speedup(w, hw, "bgw") > speedup(w, hw, "bh08")


def test_cost_model_table1_ordering():
    """Table I: BGW comm >> BH08 comm >> COPML comm."""
    k, t = case2_params(50)
    w = Workload(m=9019, d=3073, n=50, k=k, t=t, iters=50)
    bgw = mpc_baseline_costs(w, scheme="bgw")
    bh = mpc_baseline_costs(w, scheme="bh08")
    ours = copml_costs(w)
    assert bgw["comm_s"] > bh["comm_s"] > ours["comm_s"]
    assert bh["comp_s"] > ours["comp_s"]
