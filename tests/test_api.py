"""repro.api facade: the (workload, protocol, engine) axes.

The copml goldens below were produced by the PRE-refactor
Copml.train_jit / train_sharded (commit e179bb5, before the api layer
existed) on the smoke workload -- the facade must reproduce them
bit-for-bit through every engine.
"""

import hashlib
import importlib
import os
import sys

import jax
import numpy as np
import pytest

from repro import api
from repro.core import secure_agg as sa
from repro.core.baselines import MpcBaseline
from repro.core.protocol import Copml

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# smoke workload, key=PRNGKey(0), 10 iterations (pre-refactor outputs)
GOLDEN_W = [0.25, -0.375, 0.375, 0.5, -0.125, 0.25, 0.875, 1.25, -0.5,
            -1.125, -0.5, 0.125]
GOLDEN_SHARES_SHA = \
    "459aaa671b3d6708b4918f1e54b29e083cecf6c85b5b617f882720596399afaf"
GOLDEN_HIST_SHA = \
    "343e87b79c6ece3608774a43160dccbb80ef214111bdb0f9f9c066ead77f9e80"


def _sha(arr, dtype):
    return hashlib.sha256(np.asarray(arr, dtype).tobytes()).hexdigest()


@pytest.fixture(scope="module")
def copml_jit():
    return api.fit("smoke", "copml", "jit", key=0, iters=10, history=True)


# --------------------------------------------------- copml engine bit-exact


def test_copml_jit_matches_prerefactor_golden(copml_jit):
    res = copml_jit
    np.testing.assert_array_equal(
        np.asarray(res.weights, np.float64), np.asarray(GOLDEN_W))
    assert _sha(res.state.w_shares, np.int32) == GOLDEN_SHARES_SHA
    assert _sha(res.history, np.float32) == GOLDEN_HIST_SHA
    assert res.triple == ("smoke", "copml", "jit")


def test_copml_eager_bit_exact_vs_jit(copml_jit):
    res = api.fit("smoke", "copml", "eager", key=0, iters=10, history=True)
    np.testing.assert_array_equal(res.weights, copml_jit.weights)
    np.testing.assert_array_equal(res.history, copml_jit.history)
    np.testing.assert_array_equal(np.asarray(res.state.w_shares),
                                  np.asarray(copml_jit.state.w_shares))


def test_copml_sharded_matches_prerefactor_golden(copml_jit):
    """The shard_map engine on a 1-device mesh (multi-device parity is the
    slow subprocess test in test_distributed.py)."""
    res = api.fit("smoke", "copml", api.EngineSpec("sharded", devices=1),
                  key=0, iters=10, history=False)
    np.testing.assert_array_equal(res.weights, copml_jit.weights)
    assert _sha(res.state.w_shares, np.int32) == GOLDEN_SHARES_SHA
    assert res.engine == "sharded:1"


# ------------------------------------------------- all protocols, both ways


@pytest.mark.parametrize("protocol", ["copml", "mpc_baseline", "float",
                                      "poly_float", "secure_agg"])
def test_protocol_runs_on_eager_and_jit(protocol):
    """Acceptance grid: 5 protocols x {eager, jit}, one TrainResult schema."""
    results = {}
    for engine in ("eager", "jit"):
        res = api.fit("smoke", protocol, engine, key=0, iters=5)
        assert res.triple == ("smoke", protocol, engine)
        assert res.weights.shape == (12,)
        assert res.history.shape == (5, 12)
        assert res.accuracy.shape == (5,)
        assert 0.0 <= res.final_accuracy <= 1.0
        assert res.wall_time_s > 0
        assert res.iters == 5
        # history rows are snapshots, not views of the trainer's weight
        # buffer: the trajectory must actually move step to step
        assert not np.array_equal(res.history[0], res.history[-1])
        results[engine] = res
    # engines agree on what they computed (bit-exact for the field
    # protocols, float32-vs-float64 tolerance for the float paths) --
    # per step, not just at the end
    np.testing.assert_allclose(results["eager"].weights,
                               results["jit"].weights, atol=1e-5)
    np.testing.assert_allclose(results["eager"].history,
                               results["jit"].history, atol=1e-4)
    # the secured protocols learn the same task: accuracy in family
    assert abs(results["eager"].final_accuracy
               - results["jit"].final_accuracy) <= 0.05


def test_cost_model_attached_per_protocol():
    res_c = api.fit("smoke", "copml", "jit", key=0, iters=5, history=False)
    assert set(res_c.cost) == {"comm_s", "comp_s", "enc_s", "total_s"}
    res_f = api.fit("smoke", "float", "jit", key=0, iters=5, history=False)
    assert res_f.cost is None and res_f.history is None
    # Table I ordering (a PAPER-scale property: at smoke scale the fixed
    # dataset-sharing term dominates): baseline comm >> COPML comm.  The
    # cost models run on shapes only -- no training needed.
    wl = api.get_workload("cifar10_case2")
    cost_c = api.PROTOCOLS["copml"].cost(wl, 50)
    cost_m = api.PROTOCOLS["mpc_baseline"].cost(wl, 50)
    assert cost_m["comm_s"] > cost_c["comm_s"]
    assert cost_m["total_s"] > cost_c["total_s"]


# ------------------------------------------------------- deprecation shims


def test_train_method_shims_warn_and_match_facade():
    wl = api.get_workload("smoke")
    proto = Copml(wl.cfg, wl.m, wl.d)
    cx, cy = wl.client_data()
    key = jax.random.PRNGKey(0)
    res = api.fit("smoke", "copml", "jit", key=0, iters=3, history=False)

    with pytest.warns(DeprecationWarning, match="train_jit is deprecated"):
        st_j, w_j = proto.train_jit(key, cx, cy, 3)
    with pytest.warns(DeprecationWarning, match="train_eager is deprecated"):
        st_e, w_e = proto.train_eager(key, cx, cy, 3)
    with pytest.warns(DeprecationWarning,
                      match="train_sharded is deprecated"):
        st_s, w_s = proto.train_sharded(key, cx, cy, 3,
                                        mesh=None)  # all (1) visible devices
    for w, st in ((w_j, st_j), (w_e, st_e), (w_s, st_s)):
        np.testing.assert_array_equal(np.asarray(w), res.weights)
        np.testing.assert_array_equal(np.asarray(st.w_shares),
                                      np.asarray(res.state.w_shares))


# --------------------------------------- baselines routed through the api


def test_mpc_baseline_api_matches_direct_call():
    wl = api.get_workload("smoke")
    x, y, _, _ = wl.data()
    mb = MpcBaseline(wl.cfg, wl.m, wl.d, groups=3)
    _, w_direct = mb.train(jax.random.PRNGKey(0), x, y, 5)

    res_e = api.fit("smoke", "mpc_baseline", "eager", key=0, iters=5)
    res_j = api.fit("smoke", "mpc_baseline", "jit", key=0, iters=5)
    # same key schedule end-to-end: the facade IS the direct call
    np.testing.assert_array_equal(np.asarray(w_direct), res_e.weights)
    np.testing.assert_array_equal(res_e.weights, res_j.weights)
    assert abs(res_e.final_accuracy - res_j.final_accuracy) < 1e-9


def test_secure_agg_api_matches_direct_call():
    """api.fit('secure_agg') == a hand-rolled loop over
    secure_agg.secure_aggregate with the same per-step fold_in schedule."""
    wl = api.get_workload("smoke")
    cx, cy = wl.client_data()
    cfg = sa.SecureAggConfig(n_clients=wl.n_clients, t=wl.cfg.t)
    xs, ys, mask = sa._padded_clients(cx, cy)
    key = jax.random.PRNGKey(0)
    w = np.zeros(wl.d, np.float32)
    for t in range(5):
        g = np.asarray(sa._client_mean_grads(xs, ys, mask, w))
        grads = [{"g": g[j]} for j in range(cfg.n_clients)]
        mean = sa.secure_aggregate(jax.random.fold_in(key, t), grads, cfg)
        w = w - wl.cfg.eta * np.asarray(mean["g"], np.float32)

    res_e = api.fit("smoke", "secure_agg", "eager", key=0, iters=5)
    res_j = api.fit("smoke", "secure_agg", "jit", key=0, iters=5)
    np.testing.assert_allclose(res_e.weights, w, atol=1e-6)
    np.testing.assert_allclose(res_j.weights, w, atol=1e-5)
    assert abs(res_e.final_accuracy - res_j.final_accuracy) <= 0.05


# ----------------------------------------------------- axes and registries


def test_engine_spec_parsing():
    assert api.parse_engine("eager").kind == "eager"
    assert api.parse_engine("jit").label == "jit"
    sp = api.parse_engine("sharded:4")
    assert (sp.kind, sp.devices) == ("sharded", 4)
    from repro.core import meshutil
    mesh = meshutil.client_mesh(1)
    sp = api.parse_engine(mesh)                    # a Mesh IS an engine spec
    assert sp.kind == "sharded" and sp.resolve_mesh() is mesh
    assert sp.label == "sharded:1"
    with pytest.raises(ValueError):
        api.parse_engine("warp")
    with pytest.raises(ValueError):
        api.parse_engine("jit:4")
    with pytest.raises(ValueError):
        api.EngineSpec("jit", devices=4)
    with pytest.raises(ValueError, match="devices must be >= 1"):
        api.parse_engine("sharded:0")       # not an empty mesh
    with pytest.raises(ValueError):
        api.EngineSpec("jit", devices=0)    # 0 is not "unset"


def test_workload_registry():
    names = api.workload_names()
    for expected in ("smoke", "quickstart", "cifar10_like", "gisette_like",
                     "cifar10_case1", "cifar10_case2", "gisette_case1",
                     "pod512", "smoke_straggler", "engine_micro",
                     "mnist10_like", "linreg_smoke"):
        assert expected in names, expected
    wl = api.get_workload("cifar10_case1")         # paper Section V-A shape
    assert (wl.m, wl.d, wl.n_clients) == (9019, 3073, 50)
    assert wl.cfg.eta == 1.0                       # paper eta fits the field
    # every registered workload must be constructible as a COPML driver
    # (pod512's eta is auto-scaled so the truncation depth fits 26 bits)
    for name in api.workload_names():
        Copml(api.get_workload(name).cfg, api.get_workload(name).m,
              api.get_workload(name).d)
    assert api.WORKLOADS["smoke"] is api.get_workload("smoke")
    with pytest.raises(KeyError, match="unknown workload"):
        api.get_workload("nope")
    # eval split plumbing: *_like workloads hold out test rows
    x, y, xt, yt = api.get_workload("cifar10_like").data()
    assert x.shape == (480, 96) and xt.shape == (160, 96)
    # cached datasets are frozen -- a caller mutating them would silently
    # corrupt every later fit of the same shape
    with pytest.raises(ValueError, match="read-only"):
        x[0, 0] = 1.0
    # ad-hoc instances pass straight through fit's resolution
    assert api.get_workload("smoke").client_data()[0][0].shape[1] == 12


def test_protocol_registry_and_validation():
    assert api.protocol_names() == ("copml", "float", "mpc_baseline",
                                    "poly_float", "secure_agg")
    with pytest.raises(KeyError, match="unknown protocol"):
        api.fit("smoke", "quantum", "jit")
    with pytest.raises(ValueError, match="supports engines"):
        api.fit("smoke", "float", "sharded")       # sharded is copml-only
    # an EXPLICIT straggler subset on a protocol without subset decoding
    # is an error, not a silently-ignored argument ...
    with pytest.raises(ValueError, match="straggler-subset"):
        api.fit("smoke", "float", "jit", subset=(0, 1, 2))
    # ... but a workload's DEFAULT subset only binds protocols that can
    # decode one, so smoke_straggler still fits everywhere
    res = api.fit("smoke_straggler", "mpc_baseline", "jit", iters=2)
    assert res.triple == ("smoke_straggler", "mpc_baseline", "jit")
    with pytest.raises(ValueError, match="subset must be None"):
        api.fit("smoke", "copml", "jit", subset="most")


def test_straggler_subset_workload():
    """smoke_straggler's default subset (last R clients) trains the same
    model as the first-R subset -- recovery threshold via the facade --
    and subset='all' overrides the default with a full-decode fit."""
    res_last = api.fit("smoke_straggler", "copml", "jit", key=0)
    res_first = api.fit("smoke_straggler", "copml", "jit", key=0,
                        subset=tuple(range(10)))
    np.testing.assert_array_equal(res_last.weights, res_first.weights)
    res_all = api.fit("smoke_straggler", "copml", "jit", key=0,
                      subset="all")
    res_empty = api.fit("smoke_straggler", "copml", "jit", key=0, subset=())
    np.testing.assert_array_equal(res_all.weights, res_empty.weights)
    np.testing.assert_array_equal(res_all.weights, res_last.weights)


# ---------------------------------------------- objective conformance grid
#
# The SecureObjective split's acceptance: every protocol trains the two
# new objectives through the same facade, eager and jit agree, and the
# learned model clears a pinned floor (multi-class argmax accuracy /
# linreg R^2; chance is 0.1 / 0.0).  Iteration counts are FIXED so the
# compiled programs are shared with the bit-exactness tests below.

MC_ITERS = 8          # mnist10_like grid + engine-parity iterations
LR_ITERS = 12         # linreg_smoke default


@pytest.mark.parametrize("protocol", ["copml", "mpc_baseline", "float",
                                      "poly_float", "secure_agg"])
@pytest.mark.parametrize("workload,iters,floor,d_model", [
    ("mnist10_like", MC_ITERS, 0.55, (24, 10)),
    ("linreg_smoke", LR_ITERS, 0.60, (12,)),
])
def test_objective_conformance_grid(protocol, workload, iters, floor,
                                    d_model):
    results = {}
    for engine in ("eager", "jit"):
        res = api.fit(workload, protocol, engine, key=0, iters=iters)
        assert res.weights.shape == d_model
        assert res.history.shape == (iters,) + d_model
        assert res.accuracy.shape == (iters,)
        assert np.all(np.isfinite(res.history))
        assert res.final_accuracy >= floor, (protocol, res.final_accuracy)
        if len(d_model) == 2:             # matrix objective: per-class row
            assert res.per_class_accuracy.shape == (d_model[1],)
            assert np.nanmin(res.per_class_accuracy) >= 0.0
        else:
            assert res.per_class_accuracy is None
        results[engine] = res
    np.testing.assert_allclose(results["eager"].weights,
                               results["jit"].weights, atol=1e-4)
    assert abs(results["eager"].final_accuracy
               - results["jit"].final_accuracy) <= 0.05


def test_multiclass_copml_bit_exact_across_engines():
    """The (d, C) matrix-model path is engine-invariant bit for bit:
    eager == jit == sharded (1-device mesh; the 4-device run is the slow
    subprocess in test_distributed.py)."""
    res_j = api.fit("mnist10_like", "copml", "jit", key=0, iters=MC_ITERS,
                    history=True)
    res_e = api.fit("mnist10_like", "copml", "eager", key=0, iters=MC_ITERS,
                    history=True)
    np.testing.assert_array_equal(res_e.weights, res_j.weights)
    np.testing.assert_array_equal(res_e.history, res_j.history)
    np.testing.assert_array_equal(np.asarray(res_e.state.w_shares),
                                  np.asarray(res_j.state.w_shares))
    res_s = api.fit("mnist10_like", "copml",
                    api.EngineSpec("sharded", devices=1), key=0,
                    iters=MC_ITERS, history=True)
    np.testing.assert_array_equal(res_s.weights, res_j.weights)
    np.testing.assert_array_equal(res_s.history, res_j.history)
    # the trajectory moves and the cost model prices the C-wide exchange:
    # dearer than one binary run, far cheaper than C separate runs
    # (encode-once amortization, measured by the `multiclass` bench stage)
    assert not np.array_equal(res_j.history[0], res_j.history[-1])
    import dataclasses

    from repro.core import objectives
    wl = api.get_workload("mnist10_like")
    wl_bin = dataclasses.replace(wl, name="mnist10_bin",
                                 objective=objectives.BINARY_LOGISTIC)
    cost_mc = api.PROTOCOLS["copml"].cost(wl, MC_ITERS)
    cost_bin = api.PROTOCOLS["copml"].cost(wl_bin, MC_ITERS)
    assert cost_mc["comm_s"] > cost_bin["comm_s"]          # C-wide model
    assert cost_mc["comm_s"] < 10 * cost_bin["comm_s"]     # << C separate runs


def test_legacy_accuracy_of_rejects_matrix_models():
    """The pre-objective binary scorer guards against (d, C) weights
    instead of broadcasting into a meaningless mean."""
    x = np.zeros((4, 3))
    with pytest.raises(ValueError, match="objective.score"):
        api.accuracy_of(np.zeros((3, 10)), x, np.zeros(4))


def test_accuracy_curve_matrix_history():
    """Regression: a (iters, d, C) matrix-model history must fail fast
    with the SAME named error BEFORE iterating (it used to crash on the
    first history row inside the loop), and scoring it with the
    workload's objective must work."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(20, 3))
    y = rng.integers(0, 4, size=20)
    hist = rng.normal(size=(5, 3, 4))           # (iters, d, C=4)
    with pytest.raises(ValueError, match="objective.score"):
        api.accuracy_curve(hist, x, y)
    obj = api.multiclass_logistic(4)
    curve = api.accuracy_curve(hist, x, y, objective=obj)
    assert curve.shape == (5,)
    assert curve[0] == obj.score(hist[0], x, y)
    # vector histories keep working without an objective
    yb = rng.integers(0, 2, size=20)
    vec = api.accuracy_curve(rng.normal(size=(5, 3)), x, yb)
    assert vec.shape == (5,) and np.all((0 <= vec) & (vec <= 1))


def test_multiclass_faultplan_bit_exact():
    """A churned multi-class run equals the fault-free run bit for bit
    (LCC decode invariance on the matrix-model path), and adversarial
    contributions are really excluded."""
    from repro.core import objectives
    from repro.core.protocol import CopmlConfig
    wl = api.Workload(name="ovr3_faults", m=78, d=6,
                      cfg=CopmlConfig(n_clients=13, k=3, t=1), seed=2,
                      iters=3, objective=objectives.multiclass_logistic(3))
    plan = api.FaultPlan.random(13, 3, seed=4, straggle_p=0.3,
                                n_adversaries=1, min_available=10)
    assert not plan.is_fault_free and plan.has_adversaries
    base = api.fit(wl, "copml", "jit", key=1, iters=3, history=True)
    churn = api.fit(wl, "copml", "jit", key=1, iters=3, history=True,
                    faults=plan)
    np.testing.assert_array_equal(churn.weights, base.weights)
    np.testing.assert_array_equal(churn.history, base.history)
    np.testing.assert_array_equal(churn.availability, plan.available)
    # eager replays the same plan identically
    churn_e = api.fit(wl, "copml", "eager", key=1, iters=3, history=True,
                      faults=plan)
    np.testing.assert_array_equal(churn_e.weights, churn.weights)


# ----------------------------------------------------------- cli + harness


def test_cli_list_and_fit(capsys):
    from repro.api import cli
    cli.main(["--list"])
    out = capsys.readouterr().out
    assert "copml" in out and "sharded" in out and "smoke" in out
    assert "ovr10" in out and "linreg" in out      # objective registry
    cli.main(["smoke", "--protocol", "float", "--engine", "jit",
              "--iters", "5"])
    out = capsys.readouterr().out
    assert "smoke x float x jit" in out


def test_benchmark_stage_registry():
    """benchmarks/run.py discovers stages from a registry and stamps every
    row with its (workload, protocol, engine) triple."""
    if _REPO not in sys.path:
        sys.path.insert(0, _REPO)
    brun = importlib.import_module("benchmarks.run")
    stages = brun.build_stages()
    assert set(stages) >= {"kernel_micro", "engine", "distributed",
                           "resilience",
                           "procnet", "multiclass", "fig3", "fig4",
                           "table1", "table2", "roofline"}
    for s in stages.values():
        assert len(s.triple) == 3, s
        assert s.doc
    # unknown stage names are an error, not silently skipped
    with pytest.raises(SystemExit):
        brun.main(["--stage", "nope"])


def test_benchmark_json_trajectory_files(tmp_path):
    """--json writes one BENCH_<stage>.json per executed stage (stage,
    triple, rows) -- the perf-trajectory artifact CI uploads; a *.json
    target keeps the legacy combined dump."""
    import json
    if _REPO not in sys.path:
        sys.path.insert(0, _REPO)
    brun = importlib.import_module("benchmarks.run")
    stages = brun.build_stages()
    rows = [{"stage": "engine", "name": "engine/jit", "us_per_call": 12.5,
             "derived": "ok", "workload": "engine_micro",
             "protocol": "copml", "engine": "jit"},
            {"stage": "multiclass", "name": "multiclass/modeled_comm_ratio",
             "us_per_call": 0.0, "derived": "3.10x", "workload":
             "mnist10_like", "protocol": "copml", "engine": "jit"}]
    paths = brun.write_json(str(tmp_path), rows,
                            [("roofline", "RuntimeError('x')")], stages)
    names = {os.path.basename(p) for p in paths}
    assert names == {"BENCH_engine.json", "BENCH_multiclass.json",
                     "BENCH_roofline.json"}
    mc = json.load(open(tmp_path / "BENCH_multiclass.json"))
    assert mc["stage"] == "multiclass"
    assert mc["triple"] == ["mnist10_like", "copml", "jit"]
    assert mc["rows"][0]["derived"] == "3.10x" and mc["failure"] is None
    assert json.load(open(tmp_path / "BENCH_roofline.json"))["failure"]
    combined = tmp_path / "all.json"
    brun.write_json(str(combined), rows, [], stages)
    assert len(json.load(open(combined))["rows"]) == 2
