"""Quantization (App. A) + optimizer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F, quantize
from repro.optim import optimizers


@given(st.floats(min_value=-100, max_value=100,
                 allow_nan=False, allow_infinity=False),
       st.integers(min_value=0, max_value=10))
@settings(max_examples=100, deadline=None)
def test_quantize_roundtrip(x, lx):
    q = quantize.quantize(jnp.asarray([x], jnp.float32), lx)
    back = float(quantize.dequantize(q, lx)[0])
    assert abs(back - x) <= 0.5 / (1 << lx) + 1e-5


def test_phi_embedding_negative():
    q = quantize.quantize(jnp.asarray([-1.0, 1.0]), 3)
    assert int(q[0]) == F.P - 8 and int(q[1]) == 8


def test_signed_value():
    v = quantize.signed_value(jnp.asarray([F.P - 5, 5], jnp.int32))
    np.testing.assert_array_equal(np.asarray(v), [-5, 5])


def test_noise_variance_formula():
    assert quantize.quantization_noise_variance(3073, 9019, 21) > 0


@pytest.mark.parametrize("name", ["adamw", "sgdm", "adafactor"])
def test_optimizer_descends_quadratic(name):
    opt = optimizers.make(name, optimizers.OptConfig(
        name=name, lr=0.1, weight_decay=0.0))
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    state = opt.init(params)
    for step in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params,
                                      jnp.asarray(step, jnp.int32))
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adafactor_state_is_factored():
    opt = optimizers.make("adafactor")
    params = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((7,))}
    st_ = opt.init(params)
    assert st_["f"]["w"]["vr"].shape == (64,)
    assert st_["f"]["w"]["vc"].shape == (32,)
    assert st_["f"]["b"]["v"].shape == (7,)


def test_grad_clip():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = optimizers.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(optimizers.global_norm(clipped)) == pytest.approx(1.0, rel=1e-3)
