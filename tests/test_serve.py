"""Secure serving conformance: field-exactness, queue properties, API.

The serving contract (docs/ARCHITECTURE.md, serving data flow):

* the in-field logits of the secure path equal the quantized reference
  scorer BIT FOR BIT, on every engine (eager / jit / sharded) and both
  model shapes ((d,) and (d, C));
* predictions agree with opening-then-scoring within quantization
  tolerance (the only divergence source is the lx/lw rounding);
* the model never leaves the share domain: the CodedModel is per-client
  shares whose any-T+1 reconstruction is the quantized model;
* the micro-batch queue preserves submission order, flushes on window
  expiry, and zero-pads ragged tails to the one compiled batch shape.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.core import quantize, shamir
from repro.serve import coded
from repro.serve.queue import MicroBatchQueue

SERVE_ENGINES = ["eager", "jit", "sharded:1"]


@pytest.fixture(scope="module")
def smoke_result():
    return api.fit("smoke", "copml", "jit", history=False)


@pytest.fixture(scope="module")
def mnist_result():
    return api.fit("mnist10_like", "copml", "jit", iters=6, history=False)


def _eval_queries(workload, n=24):
    x, y = api.get_workload(workload).eval_set()
    return np.asarray(x[:n], np.float32), np.asarray(y[:n])


# ---------------------------------------------------- field-exact conformance

@pytest.mark.parametrize("engine", SERVE_ENGINES)
@pytest.mark.parametrize("workload,fixture", [
    ("smoke", "smoke_result"),            # (d,) vector model
    ("mnist10_like", "mnist_result"),     # (d, C) matrix model
])
def test_secure_scores_bit_exact_vs_reference(engine, workload, fixture,
                                              request):
    """In-field secure logits == quantized reference scorer, exactly."""
    res = request.getfixturevalue(fixture)
    wl = api.get_workload(workload)
    x, _ = _eval_queries(workload, 16)
    srv = api.serve(workload, res, engine, batch_size=8)
    secure = srv.score_field(x)
    ref = np.asarray(coded.reference_scores(res.weights, x, wl.cfg))
    np.testing.assert_array_equal(secure, ref)
    assert srv.model.from_shares        # copml state: model never opened


def test_predictions_within_quantization_tolerance(smoke_result):
    """Float logits differ from opening-then-scoring only by the query
    quantization: |error| <= ||w||_1 * 2^-(lx+1)."""
    res = smoke_result
    wl = api.get_workload("smoke")
    x, _ = _eval_queries("smoke", 24)
    srv = api.serve("smoke", res, "jit", batch_size=8)
    secure_logits = srv.logits(x)[:, 0]
    open_logits = np.asarray(x, np.float64) @ res.weights
    bound = np.abs(res.weights).sum() * 0.5 / (1 << wl.cfg.lx)
    assert np.max(np.abs(secure_logits - open_logits)) <= bound + 1e-4


def test_argmax_agreement_with_opened_model(mnist_result):
    """Matrix-model argmax decisions match opened-model scoring on the
    eval set (small quantization-induced disagreement allowed), and are
    EXACTLY the quantized-reference decisions."""
    res = mnist_result
    wl = api.get_workload("mnist10_like")
    x, _ = _eval_queries("mnist10_like", 64)
    srv = api.serve("mnist10_like", res, "jit", batch_size=16)
    preds, _ = srv.serve(x)
    opened = np.argmax(np.asarray(x, np.float64) @ res.weights, axis=1)
    assert (preds == opened).mean() >= 0.9
    ref = np.asarray(coded.reference_scores(res.weights, x, wl.cfg))
    np.testing.assert_array_equal(preds, np.argmax(
        np.asarray(quantize.dequantize(ref, wl.cfg.lz)), axis=1))


def test_engines_bit_exact_to_each_other(smoke_result):
    x, _ = _eval_queries("smoke", 16)
    outs = [api.serve("smoke", smoke_result, e, batch_size=8).score_field(x)
            for e in SERVE_ENGINES]
    for other in outs[1:]:
        np.testing.assert_array_equal(outs[0], other)


# ----------------------------------------------------------- the share domain

def test_model_stays_secret_shared(smoke_result):
    """The CodedModel is genuine Shamir sharing: any T+1 shares open to
    the quantized model, and the shares lie at the protocol's serving
    lambdas (NOT the default 1..N points)."""
    res = smoke_result
    wl = api.get_workload("smoke")
    srv = api.serve("smoke", res, "jit")
    model = srv.model
    assert model.points == coded.serving_points(wl.cfg)
    wq = np.asarray(quantize.quantize(
        np.asarray(res.weights, np.float32), wl.cfg.lw))
    opened = np.asarray(shamir.reconstruct(
        model.w_stack, model.t, model.points))[:, 0]
    np.testing.assert_array_equal(opened, wq)
    # a straggler subset (the LAST T+1 shares) opens the same secret
    sub = tuple(range(model.n - model.t - 1, model.n))
    opened2 = np.asarray(shamir.reconstruct(
        model.w_stack, model.t, model.points, subset=sub))[:, 0]
    np.testing.assert_array_equal(opened2, wq)


def test_encode_fallback_without_share_state(smoke_result):
    """Results without protocol-native shares (state=None) still serve
    from fresh shares of the quantized weights -- same exact scores."""
    import dataclasses
    res = dataclasses.replace(smoke_result, state=None)
    wl = api.get_workload("smoke")
    x, _ = _eval_queries("smoke", 8)
    srv = api.serve("smoke", res, "eager", batch_size=8)
    assert not srv.model.from_shares
    ref = np.asarray(coded.reference_scores(res.weights, x, wl.cfg))
    np.testing.assert_array_equal(srv.score_field(x), ref)


# ------------------------------------------------------------- the front door

def test_serve_rejects_proc_engine(smoke_result):
    with pytest.raises(ValueError, match="future work"):
        api.serve("smoke", smoke_result, "proc:4")


def test_serve_rejects_mismatched_result(smoke_result, mnist_result):
    with pytest.raises(ValueError, match="shape"):
        api.serve("mnist10_like", smoke_result)
    import dataclasses
    relabeled = dataclasses.replace(mnist_result, workload="smoke")
    with pytest.raises(ValueError, match="trained on"):
        api.serve("mnist10_like", relabeled)


def test_serve_queue_path_matches_direct_predict(smoke_result):
    """Micro-batched serving (ragged tail included) returns the same
    decisions, in submission order, as one direct predict() call."""
    x, _ = _eval_queries("smoke", 21)          # 21 = 2 full windows + tail 5
    srv = api.serve("smoke", smoke_result, "jit", batch_size=8)
    preds, stats = srv.serve(x)
    np.testing.assert_array_equal(preds, srv.predict(x))
    assert stats["queries"] == 21
    assert stats["batches"] == 3
    assert stats["padded"] == 3                # 24 slots - 21 queries
    assert stats["queries_per_s"] > 0 and stats["encode_s"] > 0


def test_serve_main_cli(capsys, smoke_result):
    from repro.api import cli
    cli.serve_main(["smoke", "--engine", "eager", "--iters", "3",
                    "--queries", "12", "--batch-size", "8"])
    out = capsys.readouterr().out
    assert "agreement with opened-model scoring" in out
    assert "q/s" in out


# ------------------------------------------------------- queue property tests

class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_queue_window_expiry_flushes_partial():
    clk = FakeClock()
    q = MicroBatchQueue(batch_size=4, window_ms=10.0, clock=clk)
    assert not q.ready()                       # empty: never ready
    q.submit(np.zeros(3))
    q.submit(np.ones(3))
    assert not q.ready()                       # 2 < 4 and window open
    clk.t += 0.0099
    assert not q.ready()
    clk.t += 0.0002                            # window expired
    assert q.ready()
    tickets, batch, n_valid = q.drain()
    assert tickets == (0, 1) and n_valid == 2
    assert batch.shape == (4, 3)
    np.testing.assert_array_equal(batch[1], np.ones(3))
    np.testing.assert_array_equal(batch[2:], np.zeros((2, 3)))
    assert len(q) == 0 and not q.ready()


def test_queue_full_batch_flushes_regardless_of_clock():
    q = MicroBatchQueue(batch_size=2, window_ms=1e9, clock=FakeClock())
    q.submit(np.zeros(2))
    assert not q.ready()
    q.submit(np.zeros(2))
    assert q.ready()


def test_queue_validates_inputs():
    with pytest.raises(ValueError, match="batch_size"):
        MicroBatchQueue(0, 5.0)
    with pytest.raises(ValueError, match="window_ms"):
        MicroBatchQueue(4, -1.0)
    q = MicroBatchQueue(4, 5.0, clock=FakeClock())
    with pytest.raises(ValueError, match="query row"):
        q.submit(np.zeros((2, 3)))
    q.submit(np.zeros(3))
    with pytest.raises(ValueError, match="dim"):
        q.submit(np.zeros(5))
    with pytest.raises(ValueError, match="empty"):
        MicroBatchQueue(4, 5.0, clock=FakeClock()).drain()


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 40), st.integers(1, 9))
def test_queue_preserves_order_and_pads(n_queries, batch_size):
    """Draining everything yields every ticket exactly once, in
    submission order, with every window exactly (batch_size, d)."""
    q = MicroBatchQueue(batch_size, window_ms=1e9, clock=FakeClock())
    rows = [np.full(2, i, np.float32) for i in range(n_queries)]
    tickets = [q.submit(r) for r in rows]
    assert tickets == list(range(n_queries))
    seen = []
    while len(q):
        tk, batch, n_valid = q.drain()
        assert batch.shape == (batch_size, 2)
        assert 1 <= n_valid <= batch_size
        for i, t in enumerate(tk):
            np.testing.assert_array_equal(batch[i], rows[t])
        np.testing.assert_array_equal(batch[n_valid:],
                                      np.zeros((batch_size - n_valid, 2)))
        seen.extend(tk)
    assert seen == list(range(n_queries))
