"""Secure multiplication (BGW + BH08) and the TruncPr truncation protocol."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import field as F, mpc, quantize, shamir, truncation


@pytest.mark.parametrize("scheme", ["bgw", "bh08"])
def test_secure_mult(rng, scheme):
    t, n = 2, 7
    a = jnp.asarray(rng.integers(0, F.P, size=(5,)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(5,)).astype(np.int32))
    sa = shamir.share(jax.random.PRNGKey(0), a, t, n)
    sb = shamir.share(jax.random.PRNGKey(1), b, t, n)
    fn = mpc.mul_bgw if scheme == "bgw" else mpc.mul_bh08
    prod_shares = fn(jax.random.PRNGKey(2), sa, sb, t)
    got = shamir.reconstruct(prod_shares, t)          # degree back to T
    np.testing.assert_array_equal(np.asarray(got), np.asarray(F.mul(a, b)))


@pytest.mark.parametrize("scheme", ["bgw", "bh08"])
def test_secure_matmul(rng, scheme):
    t, n = 1, 5
    a = rng.integers(0, F.P, size=(4, 6)).astype(np.int32)
    b = rng.integers(0, F.P, size=(6, 3)).astype(np.int32)
    sa = shamir.share(jax.random.PRNGKey(0), jnp.asarray(a), t, n)
    sb = shamir.share(jax.random.PRNGKey(1), jnp.asarray(b), t, n)
    fn = mpc.mul_bgw if scheme == "bgw" else mpc.mul_bh08
    ps = fn(jax.random.PRNGKey(2), sa, sb, t, matmul=True)
    got = shamir.reconstruct(ps, t)
    np.testing.assert_array_equal(np.asarray(got), F.np_matmul(a, b))


def test_truncation_is_stochastic_rounding():
    """z = floor(a/2^k1) + Bernoulli(frac): mean over trials ~ a/2^k1, and
    every sample is one of the two adjacent integers (paper Section III)."""
    t, n, k1, k2 = 1, 5, 6, 20
    a_val = 1000 * 64 + 13                            # frac = 13/64
    a = jnp.full((256,), a_val, jnp.int32)
    sh = shamir.share(jax.random.PRNGKey(0), a, t, n)
    out_shares = truncation.trunc_pr(jax.random.PRNGKey(1), sh, k1, k2, t)
    z = np.asarray(shamir.reconstruct(out_shares, t))
    assert set(np.unique(z)) <= {1000, 1001}
    mean = z.mean()
    assert abs(mean - (1000 + 13 / 64)) < 0.1


def test_truncation_negative_values():
    """Signed fixed-point values (field embedding p+x) truncate correctly."""
    t, n, k1, k2 = 1, 5, 4, 16
    vals = np.array([-160, -33, 17, 240], np.int64)   # multiples + offsets
    a = jnp.asarray(np.where(vals < 0, F.P + vals, vals).astype(np.int32))
    sh = shamir.share(jax.random.PRNGKey(0), a, t, n)
    outs = []
    for i in range(200):
        o = truncation.trunc_pr(jax.random.PRNGKey(i), sh, k1, k2, t)
        outs.append(np.asarray(quantize.signed_value(
            shamir.reconstruct(o, t))))
    mean = np.mean(outs, axis=0)
    np.testing.assert_allclose(mean, vals / 16, atol=0.15)


def test_statistical_gap_documented():
    assert truncation.statistical_gap(24) > 1.9      # ~2 bits at p=2^26-5
