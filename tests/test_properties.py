"""Property-based conformance for the resilience substrate.

Runs under real `hypothesis` where available, else the deterministic shim
(tests/_hypothesis_compat.py -- boundary values + seeded draws).  The two
properties the fault-injection engine is built on, stated over RANDOM
parameters rather than the unit tests' fixed ones:

* Shamir: a secret reconstructs from ANY subset of exactly T+1 of its N
  shares (the secure-aggregation budget), including via the traced-index
  reconstruct_dyn path the per-step engines use;
* LCC: decoding f-evaluations from ANY subset of exactly R = D(K+T-1)+1
  of the N coded results yields the identical field element (the COPML
  budget) -- which is precisely why a FaultPlan swap is bit-exact free.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import field as F, lagrange, shamir

MAX_SEED = 2 ** 31 - 1


def _rng_subset(rng, n: int, size: int) -> tuple:
    """A uniformly random size-`size` client subset (unsorted: order must
    not matter either)."""
    return tuple(int(i) for i in rng.permutation(n)[:size])


# --------------------------------------------------------------- shamir


@given(st.integers(0, MAX_SEED), st.integers(1, 3))
@settings(max_examples=8, deadline=None)
def test_share_reconstructs_from_any_threshold_subset(seed, t):
    """share -> reconstruct round-trip over a random subset of EXACTLY
    T+1 shares, for random secrets, N, and subset choice."""
    rng = np.random.default_rng(seed)
    n = t + 1 + int(rng.integers(1, 6))
    secret = jnp.asarray(rng.integers(0, F.P, size=(3, 4)).astype(np.int32))
    shares = shamir.share(jax.random.PRNGKey(seed), secret, t, n)
    sub = _rng_subset(rng, n, t + 1)
    rec = shamir.reconstruct(shares, t, subset=sub)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(secret))
    # the traced-index path (what the per-step fault engines run) agrees
    points = shamir.default_eval_points(n)
    rec_dyn = shamir.reconstruct_dyn(
        shares, jnp.asarray(sub, jnp.int32),
        shamir.recon_weights(points, sub))
    np.testing.assert_array_equal(np.asarray(rec_dyn), np.asarray(secret))


@given(st.integers(0, MAX_SEED))
@settings(max_examples=8, deadline=None)
def test_sum_shares_reconstruct_from_any_subset(seed):
    """The secure_agg invariant: holder-side share sums reconstruct the
    sum of secrets from any T+1 holders."""
    rng = np.random.default_rng(seed)
    t, n, j = 2, 7, 4
    secrets = jnp.asarray(rng.integers(0, F.P, size=(j, 5)).astype(np.int32))
    shares = shamir.share_batch(jax.random.PRNGKey(seed), secrets, t, n)
    summed = shares[0]
    for o in range(1, j):
        summed = F.add(summed, shares[o])        # (N_holder, 5)
    expect = np.asarray(secrets[0])
    for o in range(1, j):
        expect = np.asarray(F.add(jnp.asarray(expect), secrets[o]))
    rec = shamir.reconstruct(summed, t, subset=_rng_subset(rng, n, t + 1))
    np.testing.assert_array_equal(np.asarray(rec), expect)


# -------------------------------------------------------------- lagrange


def _coded_round(rng, k, t, r, n):
    """One COPML-style round: coded data + coded model + per-client
    f(X~_i, w~_i) evaluations of the degree-(2r+1) polynomial."""
    mk, d = 4, 3
    alphas, betas = lagrange.default_points(n, k, t)
    blocks = jnp.asarray(rng.integers(0, F.P, size=(k, mk, d)
                                      ).astype(np.int32))
    masks = jnp.asarray(rng.integers(0, F.P, size=(t, mk, d)
                                     ).astype(np.int32))
    coded = lagrange.lcc_encode(blocks, masks, alphas, betas)
    w = jnp.asarray(rng.integers(0, F.P, size=(d,)).astype(np.int32))
    wb = jnp.broadcast_to(w[None, None, :], (k, 1, d))
    vm = jnp.asarray(rng.integers(0, F.P, size=(t, 1, d)).astype(np.int32))
    wc = lagrange.lcc_encode(wb, vm, alphas, betas)[:, 0, :]
    coeffs = jnp.asarray(rng.integers(0, F.P, size=(r + 1,)
                                      ).astype(np.int32))

    def f(x, ww):
        z = F.matmul(x, ww[:, None])[:, 0]
        return F.matmul(x.T, F.evaluate_poly_dyn(coeffs, z)[:, None])[:, 0]

    evals = jnp.stack([f(coded[i], wc[i]) for i in range(n)])
    return evals, alphas, betas


@given(st.integers(0, MAX_SEED), st.integers(1, 3), st.integers(1, 2))
@settings(max_examples=6, deadline=None)
def test_decode_invariant_across_valid_subsets(seed, k, t):
    """Different random subsets of EXACTLY R evaluations from the same
    round decode to the identical result -- the zero-cost-recovery
    property the FaultPlan engines rely on step after step."""
    r = 1
    rthr = lagrange.recovery_threshold(r, k, t)
    rng = np.random.default_rng(seed)
    n = rthr + 2 + int(rng.integers(0, 3))
    evals, alphas, betas = _coded_round(rng, k, t, r, n)
    ref = None
    for _ in range(3):
        sub = sorted(_rng_subset(rng, n, rthr))
        dec = np.asarray(lagrange.lcc_decode(
            evals[jnp.asarray(sub)], [alphas[i] for i in sub], betas, k))
        if ref is None:
            ref = dec
        else:
            np.testing.assert_array_equal(dec, ref)


@given(st.integers(0, MAX_SEED))
@settings(max_examples=4, deadline=None)
def test_threshold_is_tight(seed):
    """R-1 random evaluations do NOT decode to the true value: the
    validation threshold in elastic.validate_budget is not conservative."""
    k, t, r = 2, 1, 1
    rthr = lagrange.recovery_threshold(r, k, t)
    rng = np.random.default_rng(seed)
    n = rthr + 2
    evals, alphas, betas = _coded_round(rng, k, t, r, n)
    full = sorted(_rng_subset(rng, n, rthr))
    good = np.asarray(lagrange.lcc_decode(
        evals[jnp.asarray(full)], [alphas[i] for i in full], betas, k))
    short = full[:-1]
    bad = np.asarray(lagrange.lcc_decode(
        evals[jnp.asarray(short)], [alphas[i] for i in short], betas, k))
    assert not np.array_equal(bad, good)
