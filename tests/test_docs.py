"""Docs lint as a fast-lane test: scripts/check_docs.py must pass, and its
checks must actually catch regressions (negative tests on a tmp tree)."""

import importlib.util
import os

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load(name="check_docs"):
    spec = importlib.util.spec_from_file_location(
        name, os.path.join(_REPO, "scripts", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_lint_clean():
    assert _load().main() == 0


def _fake_repo(tmp_path, readme_text):
    (tmp_path / "docs").mkdir()
    (tmp_path / "docs" / "ARCHITECTURE.md").write_text("# Arch\n")
    pkg = tmp_path / "src" / "repro" / "mystery"
    pkg.mkdir(parents=True)
    (pkg / "thing.py").write_text("x = 1\n")
    (tmp_path / "README.md").write_text(readme_text)
    mod = _load("check_docs_tmp")
    mod.ROOT = str(tmp_path)
    mod.DOC_FILES = ["README.md", os.path.join("docs", "ARCHITECTURE.md")]
    return mod


def test_docs_lint_catches_unmentioned_package(tmp_path, capsys):
    mod = _fake_repo(tmp_path, "# Repo\nnothing about the package\n")
    assert mod.main() == 1
    assert "src/repro/mystery" in capsys.readouterr().out


def test_docs_lint_catches_broken_link(tmp_path, capsys):
    mod = _fake_repo(
        tmp_path,
        "# Repo\n`repro/mystery`\n[gone](docs/NOPE.md)\n")
    assert mod.main() == 1
    assert "broken link" in capsys.readouterr().out


def test_docs_lint_catches_broken_anchor(tmp_path, capsys):
    mod = _fake_repo(
        tmp_path,
        "# Repo\n`repro/mystery`\n[anchor](docs/ARCHITECTURE.md#missing)\n")
    assert mod.main() == 1
    assert "broken anchor" in capsys.readouterr().out


def test_docs_lint_catches_undocumented_fused_knobs(tmp_path):
    """check_fused: a docs tree that drops the megakernel entry point or
    its env knobs must fail the lint."""
    mod = _fake_repo(tmp_path, "# Repo\n`repro/mystery`\n")
    (tmp_path / "docs" / "RUNNING.md").write_text("# Running\nnothing\n")
    problems = mod.check_fused()
    assert any("ops.fused_step" in p for p in problems)
    for knob in ("REPRO_FUSED_STEP", "REPRO_PALLAS_BLOCKS",
                 "REPRO_SHARDED_OVERLAP"):
        assert any(knob in p for p in problems), knob
    assert any("repro.kernels.tune" in p for p in problems)
