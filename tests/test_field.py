"""Property tests for F_p arithmetic (the substrate of every MPC op)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F

elem = st.integers(min_value=0, max_value=F.P - 1)


@given(elem, elem)
@settings(max_examples=200, deadline=None)
def test_mul_matches_int(a, b):
    got = int(F.mul(jnp.asarray(a, jnp.int32), jnp.asarray(b, jnp.int32)))
    assert got == (a * b) % F.P


@given(elem, elem, elem)
@settings(max_examples=50, deadline=None)
def test_ring_axioms(a, b, c):
    ja, jb, jc = (jnp.asarray(x, jnp.int32) for x in (a, b, c))
    assert int(F.add(ja, jb)) == (a + b) % F.P
    assert int(F.sub(ja, jb)) == (a - b) % F.P
    # distributivity
    lhs = int(F.mul(ja, F.add(jb, jc)))
    rhs = int(F.add(F.mul(ja, jb), F.mul(ja, jc)))
    assert lhs == rhs


@given(st.integers(min_value=1, max_value=F.P - 1))
@settings(max_examples=50, deadline=None)
def test_inverse(a):
    inv = int(F.inv(jnp.asarray(a, jnp.int32)))
    assert (a * inv) % F.P == 1


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=100, deadline=None)
def test_fold26(t):
    assert int(F.fold26(jnp.asarray(t, jnp.int32))) == t % F.P


@pytest.mark.parametrize("m,k,n", [(4, 7, 5), (16, 100, 8), (3, 1500, 2),
                                   (130, 1025, 7)])
def test_matmul_vs_uint64_oracle(rng, m, k, n):
    a = rng.integers(0, F.P, size=(m, k)).astype(np.int32)
    b = rng.integers(0, F.P, size=(k, n)).astype(np.int32)
    got = np.asarray(F.matmul(jnp.asarray(a), jnp.asarray(b)))
    exp = F.np_matmul(a, b)
    np.testing.assert_array_equal(got, exp)


def test_matmul_extreme_values():
    """All-(p-1) operands: worst case for limb recombination overflow."""
    a = np.full((8, F.MATMUL_CHUNK + 3), F.P - 1, np.int32)
    b = np.full((F.MATMUL_CHUNK + 3, 8), F.P - 1, np.int32)
    got = np.asarray(F.matmul(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, F.np_matmul(a, b))


def test_poly_eval(rng):
    x = rng.integers(0, F.P, size=64).astype(np.int32)
    coeffs = rng.integers(0, F.P, size=4).astype(np.int32)
    got = np.asarray(F.evaluate_poly_dyn(jnp.asarray(coeffs), jnp.asarray(x)))
    exp = [(int(coeffs[0]) + int(coeffs[1]) * v + int(coeffs[2]) * v**2
            + int(coeffs[3]) * v**3) % F.P for v in x.astype(object)]
    np.testing.assert_array_equal(got, np.asarray(exp, np.int64))


def test_host_lagrange_identity():
    pts = [3, 11, 42, 7]
    mat = F.host_lagrange_coeffs(pts, pts)
    np.testing.assert_array_equal(mat, np.eye(4, dtype=np.int32))
