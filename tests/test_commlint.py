"""commlint conformance: fixture corpus, self-run gate, corruption drills.

Mirrors tests/test_analysis.py's three layers for the comm pass family:

* fixture corpus (tests/fixtures/commlint/): one minimal worker+session
  choreography per failure mode, each firing EXACTLY the designed COM
  rule set (and the `clean` pair firing nothing);
* the live gate: `repro.analysis --pass comm` over src/repro must be
  clean with zero waivers and finish inside the CI fast-lane budget;
* corruption drills: deleting the real worker's OPENED recv must flip
  the CLI to COM001+COM005 (deadlock), and pinning the coordinator's
  OPENED step expression must flip it to COM004 -- while an unmodified
  copy stays clean.

Plus the comm-budget layer: the declarative choreography's closed-form
frame counts must equal core/cost_model.proc_net_frames for every
(procs, iters, history) combination, and a diverging cost model must
surface as COM009.
"""

import os
import shutil
import subprocess
import sys
import tempfile
import time

import pytest

from repro.analysis import analyze_paths
from repro.analysis import choreography
from repro.analysis.cache import FindingsCache
from repro.core import cost_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_REPRO = os.path.join(REPO, "src", "repro")
RUNTIME = os.path.join(SRC_REPRO, "launch", "runtime")
FIXTURES = os.path.join(REPO, "tests", "fixtures", "commlint")


def _active_rules(result):
    return sorted({f.rule for f in result.active})


def _run_cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)


# ------------------------------------------------------------- fixture corpus

CORPUS = [
    ("clean", []),
    ("drop_opened_recv", ["COM001", "COM005"]),  # orphan send -> deadlock
    ("drop_open_send", ["COM002", "COM005"]),    # unfulfillable recv
    ("inverted_enc", ["COM005"]),                # recv-before-send cycle
    ("step_const", ["COM004"]),                  # send pins step=0
    ("phase_wrong", ["COM004"]),                 # OPEN billed to "encode"
    ("adaptive_block", ["COM006"]),              # blocking collect loop
    ("recv_any_no_timeout", ["COM006"]),
    ("unknown_kind", ["COM007"]),                # net.PING not in the spec
    ("pickle_enc", ["COM008"]),                  # pickle on a data round
    ("tobytes_enc", ["COM008"]),                 # raw bytes on an array round
    ("card_single_enc", ["COM003"]),             # one send where P-1 expected
]


@pytest.mark.parametrize("case,expected", CORPUS, ids=[c[0] for c in CORPUS])
def test_fixture_corpus(case, expected):
    res = analyze_paths([os.path.join(FIXTURES, case)], passes=("comm",))
    assert _active_rules(res) == expected


def test_sec_pass_ignores_comm_fixtures():
    """Pass selection is real: the sec family alone must not fire on a
    choreography bug (and vice versa the corpus above runs comm-only)."""
    res = analyze_paths([os.path.join(FIXTURES, "step_const")],
                        passes=("sec",))
    assert _active_rules(res) == []


def test_waiver_covers_comm_findings(tmp_path):
    """A seclint-grammar pragma waives COM findings too -- both COM004s
    anchored at step_const's SHARE send line go quiet, with reasons."""
    case = tmp_path / "waived"
    shutil.copytree(os.path.join(FIXTURES, "step_const"), case)
    worker = case / "worker.py"
    src = worker.read_text()
    target = "                node.send(s, net.SHARE, step=0,"
    assert target in src
    src = src.replace(
        target,
        "                # seclint: allow[COM004] reason=fixture pins step\n"
        + target)
    worker.write_text(src)
    res = analyze_paths([str(case)], passes=("comm",))
    assert res.active == []
    assert len(res.waived) == 2
    assert all(f.rule == "COM004" and f.waiver_reason for f in res.waived)
    assert res.unused_waivers == []


# ------------------------------------------------------------- the live gate

def test_self_run_comm_clean_zero_waivers():
    t0 = time.monotonic()
    res = analyze_paths([SRC_REPRO], package="repro", passes=("comm",))
    elapsed = time.monotonic() - t0
    assert res.active == [], [str(f) for f in res.active]
    assert res.waived == []          # acceptance: clean with ZERO waivers
    assert elapsed < 30.0


def test_cli_pass_selection_and_rule_listing():
    p = _run_cli("--pass", "comm", os.path.join(FIXTURES, "clean"))
    assert p.returncode == 0, p.stdout + p.stderr
    assert "analysis[comm]" in p.stdout

    p = _run_cli("--pass", "comm", os.path.join(FIXTURES, "pickle_enc"))
    assert p.returncode == 1
    assert "COM008" in p.stdout

    p = _run_cli("--pass", "sec", os.path.join(FIXTURES, "pickle_enc"))
    assert p.returncode == 0       # comm bug invisible to the sec family

    p = _run_cli("--list-rules")
    assert p.returncode == 0
    for rid in [f"COM00{i}" for i in range(1, 10)]:
        assert rid in p.stdout


def test_cli_changed_only_smoke():
    """--changed-only must run (restricting to git-dirty files) and stay
    clean regardless of what is currently dirty."""
    p = _run_cli("--changed-only", SRC_REPRO)
    assert p.returncode == 0, p.stdout + p.stderr


# --------------------------------------------------------- corruption drills

def _runtime_copy(tmp, mutate=None):
    """Copy the real worker.py+session.py (+deps) into tmp, optionally
    mutated, and return the directory to lint."""
    d = os.path.join(tmp, "runtime")
    os.mkdir(d)
    for name in ("worker.py", "session.py", "net.py"):
        shutil.copy(os.path.join(RUNTIME, name), os.path.join(d, name))
    if mutate:
        path = os.path.join(d, mutate[0])
        with open(path) as fh:
            src = fh.read()
        assert mutate[1] in src, f"drill anchor not found in {mutate[0]}"
        with open(path, "w") as fh:
            fh.write(src.replace(mutate[1], mutate[2]))
    return d


_WORKER_OPENED_RECV = (
    "            frm = node.recv(net.OPENED, src=net.COORD, step=step,\n"
    "                            tag=net.TAG_TRUNC)")


def test_drill_deleted_recv_is_a_deadlock():
    """Deleting the worker's OPENED recv orphans the coordinator's
    broadcast AND removes a barrier leg -> COM001 + COM005."""
    with tempfile.TemporaryDirectory() as tmp:
        d = _runtime_copy(tmp, mutate=(
            "worker.py", _WORKER_OPENED_RECV, "            frm = None"))
        p = _run_cli("--pass", "comm", d)
        assert p.returncode == 1
        assert "COM001" in p.stdout and "COM005" in p.stdout


def test_drill_mutated_step_expr_is_a_pair_mismatch():
    with tempfile.TemporaryDirectory() as tmp:
        d = _runtime_copy(tmp, mutate=(
            "session.py",
            "node.send(r, net.OPENED, step=t, tag=net.TAG_TRUNC,",
            "node.send(r, net.OPENED, step=0, tag=net.TAG_TRUNC,"))
        p = _run_cli("--pass", "comm", d)
        assert p.returncode == 1
        assert "COM004" in p.stdout


def test_uncorrupted_runtime_copy_is_clean():
    with tempfile.TemporaryDirectory() as tmp:
        d = _runtime_copy(tmp)
        p = _run_cli("--pass", "comm", d)
        assert p.returncode == 0, p.stdout + p.stderr


# ------------------------------------------------------------ the comm budget

def test_choreography_matches_cost_model_closed_forms():
    for procs in (1, 2, 3, 4, 8):
        for iters in (0, 1, 2, 10):
            for history in (False, True):
                spec = choreography.frames_by_phase(procs, iters, history)
                model = cost_model.proc_net_frames(procs, iters,
                                                   history=history)
                assert spec == model, (procs, iters, history)


def test_frame_closed_forms_spot_values():
    got = choreography.frames_by_phase(4, 10, history=True)
    assert got == {
        "setup": 4 * 3 // 2 + 6 * 4,       # P(P-1)/2 HELLOs + 6P control
        "encode": 4 * 3 * 10,              # P(P-1) per step
        "exchange": 4 * 3 * 10,
        "trunc_open": 2 * 4 * 10,          # OPEN up + OPENED down
        "open_model": 4 * 10 + 4,          # hist OPENs + P RESULTs
    }
    # zero-valued phases are omitted, not reported as 0
    assert "open_model" in choreography.frames_by_phase(2, 0, history=False)
    assert choreography.frames_by_phase(2, 0)["open_model"] == 2


def test_diverging_cost_model_is_com009(monkeypatch):
    def wrong(procs, iters, history=False):
        good = dict(choreography.frames_by_phase(procs, iters, history))
        good["encode"] = good.get("encode", 0) + 1
        return good
    monkeypatch.setattr(cost_model, "proc_net_frames", wrong)
    res = analyze_paths([RUNTIME], passes=("comm",))
    assert "COM009" in _active_rules(res)


def test_missing_cost_model_hook_is_com009(monkeypatch):
    monkeypatch.delattr(cost_model, "proc_net_frames")
    res = analyze_paths([RUNTIME], passes=("comm",))
    assert "COM009" in _active_rules(res)


# -------------------------------------------------- cache + scoped runs

def test_findings_cache_hit_miss_invalidate(tmp_path):
    bad = os.path.join(REPO, "tests", "fixtures", "seclint", "sec001_bad.py")
    target = tmp_path / "sec001_bad.py"
    shutil.copy(bad, target)
    cpath = str(tmp_path / "cache.json")

    cache = FindingsCache(cpath)
    res = analyze_paths([str(target)], cache=cache)
    assert _active_rules(res) == ["SEC001"]
    assert cache.misses >= 1 and cache.hits == 0
    cache.save()

    cache2 = FindingsCache(cpath)          # fresh load from disk
    res = analyze_paths([str(target)], cache=cache2)
    assert _active_rules(res) == ["SEC001"]  # findings survive the cache
    assert cache2.hits >= 1 and cache2.misses == 0

    st = os.stat(target)
    os.utime(target, ns=(st.st_atime_ns, st.st_mtime_ns + 1_000_000))
    cache3 = FindingsCache(cpath)
    analyze_paths([str(target)], cache=cache3)
    assert cache3.misses >= 1               # mtime change invalidates


def test_only_files_restricts_but_keeps_the_group():
    """Scoping the run to worker.py alone must still lint it against its
    session.py counterpart (groups are discovered from the full index)."""
    worker = os.path.abspath(os.path.join(RUNTIME, "worker.py"))
    res = analyze_paths([SRC_REPRO], package="repro", passes=("comm",),
                        only_files={worker})
    assert res.active == []
    assert res.files == [worker]
