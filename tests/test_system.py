"""End-to-end system tests: training loop, checkpoint/restart equivalence,
elastic re-mesh, serving, data determinism, sharding rules, dry-run lite."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data import pipeline
from repro.models import model_zoo as MZ
from repro.models import lm_serving as serving
from repro.train import checkpoint as ckpt_lib, elastic, trainer


def test_lm_training_reduces_loss(tmp_path):
    cfg = registry.smoke_config("smollm-360m")
    tcfg = trainer.TrainConfig(steps=12, global_batch=4, seq_len=64,
                               log_every=1, ckpt_dir=None)
    _, hist = trainer.train(cfg, tcfg)
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.98


def test_checkpoint_restart_exact_continuation(tmp_path):
    """Fault-tolerance contract: train 8 steps straight == train 5, crash,
    restore, train 3 more (deterministic data keyed by step)."""
    cfg = registry.smoke_config("smollm-360m")
    d = str(tmp_path / "ck")
    t1 = trainer.TrainConfig(steps=8, global_batch=2, seq_len=32,
                             log_every=1, ckpt_dir=None, seed=7)
    params_straight, _ = trainer.train(cfg, t1)

    t2 = trainer.TrainConfig(steps=5, global_batch=2, seq_len=32,
                             log_every=1, ckpt_dir=d, ckpt_every=4, seed=7)
    trainer.train(cfg, t2)                       # saves step 4
    t3 = trainer.TrainConfig(steps=8, global_batch=2, seq_len=32,
                             log_every=1, ckpt_dir=d, ckpt_every=100, seed=7)
    params_resumed, _ = trainer.train(cfg, t3)   # restores step 4, runs 5..7

    for a, b in zip(jax.tree.leaves(params_straight),
                    jax.tree.leaves(params_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_checkpoint_ignores_partial_writes(tmp_path):
    ck = ckpt_lib.Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(4.0)}
    ck.save(3, tree, blocking=True)
    # simulate a crashed write: directory without manifest
    os.makedirs(tmp_path / "step_0000000009")
    restored, step = ck.restore(tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.arange(4.0))


def test_checkpoint_async_then_wait(tmp_path):
    ck = ckpt_lib.Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.ones((128, 128))})
    ck.wait()
    assert ck.list_steps() == [1]


def test_elastic_replan_and_budgets():
    m = elastic.replan_mesh(1, prefer_model=16)
    assert m.size == 1
    b = elastic.straggler_budget(n=50, k=10, t=7)
    assert b.recovery_threshold == 3 * 16 + 1 and b.tolerable == 1
    b2 = elastic.secure_agg_budget(n=16, t=3)
    assert b2.tolerable == 12


def test_serving_generates(tmp_path):
    cfg = registry.smoke_config("smollm-360m")
    bm = MZ.build(cfg)
    params = bm.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    out, stats = serving.generate(
        cfg, params, prompts, serving.ServeConfig(max_new_tokens=4,
                                                  cache_len=32))
    assert out.shape == (2, 12)
    assert stats["tokens_per_s"] > 0


def test_data_determinism_and_host_slicing():
    cfg = pipeline.LmDataConfig(vocab=128, seq_len=16, global_batch=8,
                                seed=3)
    b1 = pipeline.lm_batch(cfg, 5)
    b2 = pipeline.lm_batch(cfg, 5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = pipeline.lm_batch(cfg, 6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_partition_normalize_drops_bad_axes():
    from jax.sharding import PartitionSpec as P
    from repro.core import meshutil
    from repro.sharding import partition
    mesh = meshutil.make_mesh((1, 1), ("data", "model"))
    sp = partition.normalize(P(("pod", "data"), "model"), (7, 13), mesh)
    # "pod" absent -> dropped; sizes 1 always divide
    assert len(tuple(sp)) == 2
    sp2 = partition.normalize(P("model"), (10,), mesh)
    assert tuple(sp2) in ((("model",),), ("model",), (None,))


def test_zero_spec_shards_largest_free_dim():
    from repro.core import meshutil
    from repro.sharding import partition
    mesh = meshutil.make_mesh((1, 1), ("data", "model"))
    sp = partition.zero_spec((None, "model", None, None),
                             (48, 128, 2048, 768), mesh)
    assert sp[2] == "data"      # largest unsharded dim gets the data axis


def test_secure_agg_training_integration():
    """Beyond-paper path: LM trained with COPML-coded secure aggregation."""
    from repro.core.secure_agg import SecureAggConfig
    cfg = registry.smoke_config("smollm-360m")
    tcfg = trainer.TrainConfig(
        steps=4, global_batch=4, seq_len=32, log_every=1,
        secure_agg=SecureAggConfig(n_clients=4, t=1, lq=14, clip=4.0))
    _, hist = trainer.train_secure(cfg, tcfg)
    assert np.isfinite(hist[-1]["loss"])


@pytest.mark.slow
def test_dryrun_subprocess_tiny():
    """The dry-run entry point end-to-end (fresh process so XLA_FLAGS=512
    applies), one small cell on both production meshes."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-tiny", "--shape", "train_4k", "--mesh", "both"],
        capture_output=True, text=True, env=env, cwd=os.path.dirname(
            os.path.dirname(os.path.abspath(__file__))), timeout=1500)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "all requested cells compiled" in out.stdout
