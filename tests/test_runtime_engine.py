"""proc engine conformance: real OS processes + sockets, bit-exact COPML.

The goldens are the SAME pre-refactor pins test_api.py holds for the jit
engine (smoke, key=PRNGKey(0), 10 iterations) -- re-declared here so a
drift in either file's constants is caught, not papered over.  The proc
engine must reproduce them over real localhost TCP with measured (not
modeled) communication, and a timeout-induced straggler run must decode
from the surviving R-subset to the SAME bits (LCC decode invariance under
real network timing).
"""

import hashlib
import io
import os
import subprocess
import sys
from contextlib import redirect_stdout

import numpy as np
import pytest

from repro import api
from repro.analysis import choreography
from repro.api import engine as engine_mod

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# smoke workload, key=PRNGKey(0), 10 iterations (pre-refactor outputs;
# must stay equal to tests/test_api.py's copies)
GOLDEN_W = [0.25, -0.375, 0.375, 0.5, -0.125, 0.25, 0.875, 1.25, -0.5,
            -1.125, -0.5, 0.125]
GOLDEN_SHARES_SHA = \
    "459aaa671b3d6708b4918f1e54b29e083cecf6c85b5b617f882720596399afaf"
GOLDEN_HIST_SHA = \
    "343e87b79c6ece3608774a43160dccbb80ef214111bdb0f9f9c066ead77f9e80"

MEASURED_PHASES = {"setup", "encode", "exchange", "trunc_open"}


def _sha(arr, dtype):
    return hashlib.sha256(np.asarray(arr, dtype).tobytes()).hexdigest()


# ------------------------------------------------------ golden conformance

def test_proc_engine_matches_jit_golden():
    """api.fit over proc:4 -- 4 worker subprocesses, real sockets -- lands
    on the exact pre-refactor bits (the PR's acceptance criterion)."""
    res = api.fit("smoke", "copml", "proc:4", key=0, iters=10, history=True)
    np.testing.assert_array_equal(
        np.asarray(res.weights, np.float64), np.asarray(GOLDEN_W))
    assert _sha(res.state.w_shares, np.int32) == GOLDEN_SHARES_SHA
    assert _sha(res.history, np.float32) == GOLDEN_HIST_SHA
    assert res.engine == "proc:4"

    mc = res.measured_comm
    assert mc is not None and mc["procs"] == 4 and mc["iters"] == 10
    # measured, not modeled: real wire bytes in every protocol phase
    assert MEASURED_PHASES <= set(mc["bytes_by_phase"])
    assert all(v > 0 for v in mc["bytes_by_phase"].values())
    assert mc["total_bytes"] == sum(mc["bytes_by_phase"].values())
    assert MEASURED_PHASES - {"setup"} <= set(mc["seconds_by_phase"])
    assert mc["wall_s"] > 0 and mc["setup_wall_s"] > 0
    assert mc["degraded_steps"] == 0          # loopback, no injected delay
    # sent-frame counts are deterministic: they must equal the static
    # choreography budget bit for bit (commlint's COM009 closed forms)
    assert mc["frames_by_phase"] == choreography.frames_by_phase(
        4, 10, history=True)
    assert mc["dropped_frames"] == {}         # nothing stale on loopback
    assert "measured" in res.summary()


def test_proc_straggler_emerges_and_stays_bit_exact():
    """A slow link (not a FaultPlan) makes rank 3 miss the decode
    deadline; the survivors' R-subset decode matches the fault-free jit
    model bit for bit -- LCC decode invariance driven by real timing."""
    ref = api.fit("smoke_straggler", "copml", "jit", key=0, subset="all",
                  history=False)
    net_cfg = api.NetConfig(links=((3, None, 0.35),), decode_timeout_s=0.05)
    res = api.fit("smoke_straggler", "copml",
                  api.EngineSpec("proc", devices=4, net=net_cfg),
                  key=0, subset="all", history=False)
    mc = res.measured_comm
    assert mc["degraded_steps"] >= 1
    # degradation drops frames at the receiver but every frame was still
    # sent: the sent-side budget stays exact while dropped_frames records
    # the stale discards.
    assert mc["frames_by_phase"] == choreography.frames_by_phase(
        mc["procs"], mc["iters"], history=False)
    assert sum(mc["dropped_frames"].values()) >= 1
    np.testing.assert_array_equal(np.asarray(res.weights),
                                  np.asarray(ref.weights))
    np.testing.assert_array_equal(np.asarray(res.state.w_shares),
                                  np.asarray(ref.state.w_shares))


@pytest.mark.slow
def test_proc_multiclass_bit_exact_vs_jit():
    """Nightly: the (d, C) matrix-model path over 4 processes."""
    ref = api.fit("mnist10_like", "copml", "jit", key=0, iters=3,
                  history=False)
    res = api.fit("mnist10_like", "copml", "proc:4", key=0, iters=3,
                  history=False)
    np.testing.assert_array_equal(np.asarray(res.weights),
                                  np.asarray(ref.weights))
    np.testing.assert_array_equal(np.asarray(res.state.w_shares),
                                  np.asarray(ref.state.w_shares))


# ------------------------------------------------------------- spec surface

def test_proc_spec_parsing_and_validation():
    assert api.parse_engine("proc").kind == "proc"
    assert api.parse_engine("proc").label == "proc"
    sp = api.parse_engine("proc:6")
    assert (sp.kind, sp.devices, sp.label) == ("proc", 6, "proc:6")
    assert "proc" in api.ENGINES and "proc" in api.engine_names()
    api.EngineSpec("proc", net=api.NetConfig(latency_s=0.1))   # valid
    with pytest.raises(ValueError, match="takes no net"):
        api.EngineSpec("jit", net=api.NetConfig())
    with pytest.raises(ValueError, match="takes no mesh"):
        api.EngineSpec("proc", mesh=object())
    with pytest.raises(ValueError, match="devices must be"):
        api.parse_engine("proc:0")


def test_proc_rejects_fault_plans():
    """The proc engine has no replay: stragglers come from the network."""
    plan = api.FaultPlan.random(13, 4, seed=0, straggle_p=0.1,
                                min_available=10)
    with pytest.raises(ValueError, match="no FaultPlan replay"):
        api.fit("smoke_straggler", "copml", "proc:4", key=0, faults=plan)


# ------------------------------------------- CLI listing == engine registry

def _cli_engines_line(out: str) -> list:
    for line in out.splitlines():
        if line.startswith("engines:"):
            return [e.strip() for e in
                    line.split(":", 1)[1].split(",") if e.strip()]
    raise AssertionError(f"no engines line in {out!r}")


def test_cli_listing_matches_registry():
    """repro-fit --list enumerates the LIVE registry, not a hardcoded
    tuple: a kind registered at runtime appears without a CLI edit."""
    from repro.api import cli
    buf = io.StringIO()
    with redirect_stdout(buf):
        cli.main(["--list"])
    assert _cli_engines_line(buf.getvalue()) == list(api.engine_names())

    api.register_engine_kind(engine_mod.EngineKind(
        "testkind", "registered by test_cli_listing_matches_registry"))
    try:
        buf = io.StringIO()
        with redirect_stdout(buf):
            cli.main(["--list"])
        listed = _cli_engines_line(buf.getvalue())
        assert listed == list(api.engine_names())
        assert "testkind" in listed
    finally:
        engine_mod.KINDS.pop("testkind", None)


def test_cli_listing_subprocess_matches_registry():
    """Same check through the real console entry point."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.api.cli", "--list"],
        cwd=_REPO, env=env, capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert _cli_engines_line(out.stdout) == list(api.engine_names())
