"""Deterministic fallback for `hypothesis` when it cannot be installed.

Installed into sys.modules by conftest.py ONLY if the real hypothesis is
absent, so `from hypothesis import given, settings, strategies as st` keeps
working everywhere.  Each @given test then runs on a handful of
deterministic examples drawn from the declared strategies: the boundary
values of every strategy first, then seeded pseudo-random draws.  This is
not property-based testing -- it is a smoke lane that keeps the 3 affected
modules collecting and exercising the same assertions on every host.
"""

from __future__ import annotations

import random
import sys
import types

_FALLBACK_EXAMPLES = 8   # "a handful": boundary cases + seeded random draws


class _Strategy:
    """A strategy is just a deterministic example generator here."""

    def __init__(self, gen):
        self._gen = gen

    def examples(self, n: int, rng: random.Random):
        return self._gen(n, rng)


def integers(min_value=None, max_value=None):
    lo = -(2 ** 31) if min_value is None else int(min_value)
    hi = 2 ** 31 - 1 if max_value is None else int(max_value)

    def gen(n, rng):
        out = []
        for v in (lo, hi, (lo + hi) // 2):
            if lo <= v <= hi and v not in out:
                out.append(v)
        while len(out) < n:
            out.append(rng.randint(lo, hi))
        return out[:n]

    return _Strategy(gen)


def floats(min_value=None, max_value=None, allow_nan=True,
           allow_infinity=True, width=64):
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)

    def gen(n, rng):
        out = []
        for v in (lo, hi, 0.0, 1.0, -1.0, (lo + hi) / 2):
            if lo <= v <= hi and v not in out:
                out.append(v)
        while len(out) < n:
            out.append(rng.uniform(lo, hi))
        return out[:n]

    return _Strategy(gen)


def sampled_from(elements):
    """Cycle through the given elements deterministically (all of them
    first, then seeded repeats) -- mirrors hypothesis.strategies
    .sampled_from for the shim's example counts."""
    elements = list(elements)

    def gen(n, rng):
        out = list(elements)[:n]
        while len(out) < n:
            out.append(rng.choice(elements))
        return out

    return _Strategy(gen)


def settings(max_examples=None, deadline=None, **_kw):
    """Records max_examples on the test; the fallback caps it anyway."""

    def deco(fn):
        fn._compat_max_examples = max_examples
        return fn

    return deco


def given(*strategies):
    """Run the wrapped test once per deterministic example tuple.

    The wrapper deliberately exposes a bare (*args, **kwargs) signature --
    no functools.wraps -- so pytest does not mistake the strategy-filled
    parameters for fixtures.
    """

    def deco(fn):
        def wrapper(*args, **kwargs):
            n = min(getattr(fn, "_compat_max_examples", None)
                    or _FALLBACK_EXAMPLES, _FALLBACK_EXAMPLES)
            rng = random.Random(0)
            columns = [s.examples(n, rng) for s in strategies]
            for row in zip(*columns):
                fn(*args, *row, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco


def install():
    """Register stub `hypothesis` + `hypothesis.strategies` modules."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strat = types.ModuleType("hypothesis.strategies")
    strat.integers = integers
    strat.floats = floats
    strat.sampled_from = sampled_from
    mod.strategies = strat
    mod.__is_repro_compat_shim__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strat
