"""FLD003: float dtype touches a field-domain array."""
import numpy as np

from repro.core import field


def float_cast(x, y):
    z = field.mul(x, y)
    return z.astype(np.float32)
