"""FLD002 no-fire: the narrow is dominated by a `% field.P` reduction."""
from repro.core import field


def narrow_reduced(x, y):
    acc = field.mul(x, y).sum(axis=0)
    return (acc % field.P).astype("int32")
