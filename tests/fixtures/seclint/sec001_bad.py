"""SEC001: a Shamir share reaches a host escape (print / np.asarray)."""
from repro.core import shamir


def leak(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    print(s)
    return s
