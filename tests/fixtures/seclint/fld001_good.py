"""FLD001 no-fire: field wrappers, or raw ops dominated by `% field.P`."""
from repro.core import field


def wrapped_scale(x, y):
    z = field.mul(x, y)
    a = field.mul_scalar(z, 3)
    b = (z * 3) % field.P
    return field.add(a, b)
