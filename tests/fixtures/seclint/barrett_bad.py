"""FLD001+FLD002: the same lazy accumulation WITHOUT a reduction site.

The raw `+`/`*` chain never reaches barrett_reduce/fold26 or `% field.P`,
so the arithmetic is unsanctioned and the narrowing cast is unreduced.
"""
from repro.core import field


def lazy_unreduced(x, y):
    z = field.mul(x, y)
    hi = field.mul(x, x)
    t = z + hi * 20
    return t.astype("int32")
