"""FLD004 no-fire: `% field.P` and small index/block moduli are fine."""
from repro.core import field


def right_modulus(x, block):
    a = x % field.P
    b = x % 2
    c = x % 128
    return a, b, c
