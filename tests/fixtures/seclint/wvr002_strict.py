"""WVR002 (strict only): a waiver that suppresses nothing."""
from repro.core import field


def fine(x, y):
    # seclint: allow[FLD001] reason=this pragma is never consumed
    return field.add(x, y)
