"""SEC002: Python control flow branches on a secret-derived value."""
from repro.core import shamir


def branch_on_share(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    if s[0] > 0:
        return 1
    return 0
