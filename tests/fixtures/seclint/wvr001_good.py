"""Well-formed waivers: trailing-pragma and line-above styles, both used."""
import numpy as np

from repro.core import shamir


def debug_dump(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    # seclint: allow[SEC001] reason=engine parity check, dumps shares only
    host = np.asarray(s)
    print(s)  # seclint: allow[SEC001] reason=trailing-style waiver
    return host
