"""SEC002 no-fire: branching on public metadata (shape) of a share is fine."""
from repro.core import shamir


def branch_on_shape(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    if s.shape[0] > 4:
        return 1
    return 0
