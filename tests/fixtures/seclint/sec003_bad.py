"""SEC003: a secret crosses into an unregistered external module."""
import pickle

from repro.core import shamir


def serialize_share(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    return pickle.dumps(s)
