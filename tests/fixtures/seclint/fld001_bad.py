"""FLD001: raw arithmetic on a field-domain array outside the wrappers."""
from repro.core import field


def raw_scale(x, y):
    z = field.mul(x, y)
    return z * 3
