"""SEC003 no-fire: secrets may flow through registered safe roots
(repro/jax/numpy device ops) and into sanctioned declassify sinks."""
import jax.numpy as jnp

from repro.core import mpc, shamir


def reshape_and_open(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    s2 = jnp.swapaxes(s, 0, 1)
    s3 = jnp.swapaxes(s2, 0, 1)
    return mpc.open_shares(s3, 1, pts)
