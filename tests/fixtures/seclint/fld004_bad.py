"""FLD004: a large modulus literal that is not field.P (2^26, off by 5)."""


def wrong_modulus(x):
    return x % 67108864
