"""SEC001: a serving-side model share is serialized raw.

The encode-once serving artifact is a stack of Shamir shares of the
model; `.tobytes()` materializes a share on the host for an ad-hoc
response payload.  The serving path's only sanctioned declassification
is `repro.serve.coded.open_logits` on per-query scores -- model-shaped
values must never leave the share domain (see servesend_good.py).
"""
from repro.core import shamir
from repro.kernels import ops as kernel_ops


def respond_with_model_blob(key, wq, xq, pts):
    shares = shamir.share(key, wq, 1, 4, pts)     # (N, d) model shares
    scores = kernel_ops.modmatmul(xq, shares[0][:, None])
    return shares[0].tobytes(), scores
