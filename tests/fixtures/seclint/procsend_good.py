"""SEC001 no-fire: the share crosses the process boundary through the
sanctioned wire sink.

`wire.share_payload` is registered as a declassify effect in
analysis/registry.py: its output is an opaque framed blob addressed to a
single shareholder, the runtime's equivalent of an `-> Opened`
annotation.  The plain bytes it returns may then touch any transport.
"""
import socket

from repro.core import shamir
from repro.launch.runtime import wire


def send_share_rows(key, secret, pts, addr):
    s = shamir.share(key, secret, 1, 4, pts)
    blob = wire.share_payload(s)
    sock = socket.create_connection(addr)
    sock.sendall(blob)
    return sock
