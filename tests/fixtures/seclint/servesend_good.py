"""SEC001 no-fire: the serving path opens per-query logits ONLY.

`repro.serve.coded.open_logits` is registered as an `open` effect in
analysis/registry.py: reconstructing any T+1 per-client score shares
yields the public (B, C') logits, and nothing model-shaped ever leaves
the share domain.  The dequantized logits may then touch the host.
"""
import numpy as np

from repro.core import quantize, shamir
from repro.serve import coded


def respond_with_logits(key, result, cfg, objective, queries):
    model = coded.encode_model(key, result, cfg, objective)
    xq = coded.quantize_queries(model, queries)
    z_shares = coded.score_shares(model, xq)      # stays secret
    logits = coded.open_logits(z_shares, model)   # sanctioned sink
    return np.asarray(quantize.dequantize(logits, model.lz))


def reshare_for_new_epoch(key, shares, pts):
    """Degree-refresh keeps the model in the share domain end to end."""
    return shamir.reshare(key, shares, 1, 4, pts)
