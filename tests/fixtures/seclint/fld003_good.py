"""FLD003 no-fire: floats only after leaving the field domain through
the dequantize boundary."""
from repro.core import field, quantize


def dequantized(x, y, lq):
    z = field.mul(x, y)
    f = quantize.dequantize(z, lq)
    return f * 0.5
