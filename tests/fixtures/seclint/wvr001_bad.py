"""WVR001: malformed waiver pragmas (no reason / unknown rule id)."""
from repro.core import shamir


def leak(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    print(s)  # seclint: allow[SEC001]
    return s  # seclint: allow[NOPE999] reason=unknown rule id
