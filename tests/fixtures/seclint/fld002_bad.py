"""FLD002: narrowing cast on an unreduced field accumulation."""
from repro.core import field


def narrow_unreduced(x, y):
    acc = field.mul(x, y).sum(axis=0)
    return acc.astype("int32")
