"""SEC001: a Shamir share is serialized onto a socket by hand.

`.tobytes()` materializes the share on the host before the unregistered
`sock.sendall` ever sees it -- the runtime's sends must go through the
sanctioned `repro.launch.runtime.wire.share_payload` sink instead
(see procsend_good.py).
"""
import socket

from repro.core import shamir


def leak_over_socket(key, secret, pts, addr):
    s = shamir.share(key, secret, 1, 4, pts)
    sock = socket.create_connection(addr)
    sock.sendall(s.tobytes())
    return sock
