"""No-fire: barrett_reduce/fold26 are sanctioned reduction sites.

Like the `% field.P` idiom, handing an expression to one of them
sanctions the raw arithmetic in the argument subtree (the mu-shift and
q*p subtract ARE the reduction), and their result is canonical in
[0, p), so a following narrowing cast passes FLD002.
"""
from repro.core import field


def lazy_recombine(x, y):
    z = field.mul(x, y)
    hi = field.mul(x, x)
    t = field.barrett_reduce(z + hi * 20)      # lazy limb accumulation
    return t.astype("int32")


def folded_sum(x, y):
    acc = field.fold26(field.mul(x, y) + field.mul(y, y))
    return acc.astype("int32")
