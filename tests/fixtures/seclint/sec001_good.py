"""SEC001 no-fire: the value is declassified through a sanctioned sink
(shamir.reconstruct) before it reaches the host."""
from repro.core import shamir


def open_and_print(key, secret, pts):
    s = shamir.share(key, secret, 1, 4, pts)
    w = shamir.reconstruct(s, 1, pts)
    print(w)
    return w
