"""commlint fixture: the minimal coordinator matching clean/worker.py."""

import pickle

from repro.launch.runtime import net, wire


def run(node, P, iters, history):
    addrs = {}
    for _ in range(P):
        frm = node.recv(net.LISTEN, timeout=5.0)
        addrs[frm.src] = pickle.loads(frm.payload)
    for r in range(P):
        node.send(r, net.SESSION, payload=pickle.dumps(
            {"procs": P, "iters": iters, "history": history,
             "addrs": addrs}))
    for r in range(P):
        node.recv(net.READY, src=r)
    for r in range(P):
        node.send(r, net.START)
    for t in range(iters):
        rows = [node.recv(net.OPEN, src=r, step=t, tag=net.TAG_TRUNC).payload
                for r in range(P)]
        opened = wire.pack_array(rows)
        for r in range(P):
            node.send(r, net.OPENED, step=t, tag=net.TAG_TRUNC,
                      payload=opened, phase="trunc_open")
        if history:
            for r in range(P):
                node.recv(net.OPEN, src=r, step=t, tag=net.TAG_HIST)
    results = {}
    for r in range(P):
        results[r] = pickle.loads(node.recv(net.RESULT, src=r).payload)
        node.send(r, net.BYE)
    return results
