"""commlint fixture [tobytes_enc]: ad-hoc bytes on an array round -> COM008"""
import json
import pickle
import traceback

from repro.launch.runtime import net, wire


def worker_entry(rank, host, port, node):
    try:
        node.connect(net.COORD, host, port)
        node.send(net.COORD, net.LISTEN, payload=pickle.dumps(
            {"host": host, "port": port}))
        sess = pickle.loads(node.recv(net.SESSION, src=net.COORD).payload)
        _run(node, sess, rank)
        node.recv(net.BYE, src=net.COORD)
    except Exception:  # noqa: BLE001 -- report ANY failure upstream
        node.send(net.COORD, net.ERR, payload=json.dumps(
            {"rank": rank, "error": traceback.format_exc()}).encode("utf-8"))


def _run(node, sess, rank):
    P, iters = sess["procs"], sess["iters"]
    node.send(net.COORD, net.READY)
    node.recv(net.START, src=net.COORD)
    w = sess["w"]
    for t in range(iters):
        for s in range(P):
            if s != rank:
                node.send(s, net.ENC, step=t,
                          payload=w.tobytes(), phase="encode")
        for s in range(P):
            if s != rank:
                node.recv(net.ENC, src=s, step=t)
        for s in range(P):
            if s != rank:
                node.send(s, net.SHARE, step=t,
                          payload=wire.share_payload(w), phase="exchange")
        got = 0
        while got < P - 1:
            frm = node.recv_any(net.SHARE, t, timeout=0.01)
            if frm is not None:
                got += 1
        node.send(net.COORD, net.OPEN, step=t, tag=net.TAG_TRUNC,
                  payload=wire.share_payload(w), phase="trunc_open")
        node.recv(net.OPENED, src=net.COORD, step=t, tag=net.TAG_TRUNC)
        if sess["history"]:
            node.send(net.COORD, net.OPEN, step=t, tag=net.TAG_HIST,
                      payload=wire.share_payload(w), phase="open_model")
    node.send(net.COORD, net.RESULT, payload=pickle.dumps(
        {"w": wire.share_payload(w)}), phase="open_model")
