"""Wire-format + Node transport properties of the proc-engine runtime.

Runs under real `hypothesis` where available, else the deterministic shim
(tests/_hypothesis_compat.py).  Covers the frame codec (round-trips over
payload sizes from empty to >64KiB, partial-read reassembly, malformed
streams), the array payload codec, the NetConfig link model, and the
per-link ordering guarantee of a live two-Node socket session under
injected latency.
"""

import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.launch.runtime import net, wire
from repro.launch.runtime.config import NetConfig

#: payload sizes spanning the interesting boundaries: empty, sub-header,
#: around the 64KiB socket-read chunk, and well past it
SIZES = (0, 1, 15, 16, 17, 1024, (1 << 16) - 1, (1 << 16) + 7, (1 << 17) + 3)


def _payload(size: int, seed: int) -> bytes:
    if size == 0:
        return b""
    rng = np.random.default_rng(seed)
    return rng.integers(0, 256, size, dtype=np.uint8).tobytes()


# ---------------------------------------------------------------- frame codec

@given(st.sampled_from(SIZES), st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_frame_round_trip(size, seed):
    payload = _payload(size, seed)
    kind = seed % 12 + 1
    src, tag, step = seed % 0x10000, (seed >> 4) % 0x10000, seed
    data = wire.encode_frame(kind, src, tag, step, payload)
    frames = wire.FrameReader().feed(data)
    assert len(frames) == 1
    f = frames[0]
    assert (f.kind, f.src, f.tag, f.step) == (kind, src, tag, step)
    assert f.payload == payload
    assert len(f) == len(data) == wire.HEADER_SIZE + size


@given(st.integers(min_value=1, max_value=4099),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_partial_read_reassembly(chunk, seed):
    """Any stream chunking yields the same frames in the same order."""
    payloads = [_payload(sz, seed + i)
                for i, sz in enumerate((0, 3, (1 << 16) + 1, 57))]
    stream = b"".join(wire.encode_frame(net.ENC, 2, 0, i, p)
                      for i, p in enumerate(payloads))
    fr = wire.FrameReader()
    got = []
    for off in range(0, len(stream), chunk):
        got.extend(fr.feed(stream[off:off + chunk]))
    fr.close()
    assert fr.pending == 0
    assert [f.step for f in got] == [0, 1, 2, 3]
    assert [f.payload for f in got] == payloads


def test_truncated_stream_is_an_error():
    data = wire.encode_frame(net.ENC, 0, 0, 0, b"x" * 100)
    fr = wire.FrameReader()
    assert fr.feed(data[:-1]) == []          # incomplete: nothing yet
    assert fr.pending == len(data) - 1
    with pytest.raises(wire.WireError, match="truncated"):
        fr.close()


def test_bad_magic_rejected():
    data = b"XX" + wire.encode_frame(net.ENC, 0, 0, 0, b"hi")[2:]
    with pytest.raises(wire.WireError, match="magic"):
        wire.FrameReader().feed(data)


def test_unknown_version_rejected():
    data = bytearray(wire.encode_frame(net.ENC, 0, 0, 0))
    data[2] = wire.VERSION + 1
    with pytest.raises(wire.WireError, match="version"):
        wire.FrameReader().feed(bytes(data))


def test_oversized_frame_rejected():
    # a header claiming a length beyond the cap fails fast, before any
    # payload byte is buffered
    hdr = wire.HEADER.pack(wire.MAGIC, wire.VERSION, net.ENC, 0, 0, 0, 2048)
    with pytest.raises(wire.WireError, match="exceeds"):
        wire.FrameReader(max_payload=1024).feed(hdr)


def test_oversized_payload_rejected_at_encode(monkeypatch):
    monkeypatch.setattr(wire, "MAX_PAYLOAD", 64)
    with pytest.raises(wire.WireError, match="exceeds"):
        wire.encode_frame(net.ENC, 0, 0, 0, b"\0" * 65)


# -------------------------------------------------------------- array payloads

@given(st.sampled_from([("<i4", ()), ("<i4", (7,)), ("<i4", (4, 5)),
                        ("<f4", (2, 3, 4)), ("<u1", (0,)),
                        ("<i8", (1, 1, 1, 6))]),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_pack_array_round_trip(spec, seed):
    dtype, shape = np.dtype(spec[0]), spec[1]
    rng = np.random.default_rng(seed)
    arr = rng.integers(0, 100, size=shape).astype(dtype)
    out = wire.unpack_array(wire.pack_array(arr))
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_array_payload_length_validated():
    blob = wire.pack_array(np.arange(6, dtype=np.int32).reshape(2, 3))
    with pytest.raises(wire.WireError, match="needs"):
        wire.unpack_array(blob[:-2])
    with pytest.raises(wire.WireError, match="shorter"):
        wire.unpack_array(b"")


def test_share_payload_is_pack_array():
    arr = np.arange(12, dtype=np.int32).reshape(3, 4)
    assert wire.share_payload(arr) == wire.pack_array(arr)


# ------------------------------------------------------------------ NetConfig

def test_link_latency_most_specific_wins():
    cfg = NetConfig(latency_s=0.01,
                    links=((None, 2, 0.5), (1, 2, 0.2), (1, None, 0.3)))
    assert cfg.link_latency(1, 2) == 0.2     # exact (src, dst) beats both
    assert cfg.link_latency(0, 2) == 0.5     # dst-only wildcard
    assert cfg.link_latency(1, 0) == 0.3     # src-only wildcard
    assert cfg.link_latency(0, 0) == 0.01    # default


def test_bandwidth_adds_serialization_delay():
    cfg = NetConfig(latency_s=0.1, bandwidth_bps=1000.0)
    assert cfg.delay(0, 1, 500) == pytest.approx(0.6)
    assert NetConfig().delay(0, 1, 10**9) == 0.0   # infinite by default


def test_from_env_reads_knobs(monkeypatch):
    monkeypatch.setenv("REPRO_PROC_LATENCY_S", "0.25")
    monkeypatch.setenv("REPRO_PROC_TIMEOUT_S", "7")
    monkeypatch.setenv("REPRO_PROC_RETRIES", "2")
    cfg = NetConfig.from_env()
    assert (cfg.latency_s, cfg.recv_timeout_s, cfg.recv_retries) \
        == (0.25, 7.0, 2)


# ------------------------------------------------- live sockets: link ordering

def test_per_link_order_preserved_under_latency():
    """Frames on one link arrive in send order even when injected delays
    differ per frame (bandwidth makes big frames slower): the receiver
    drains each connection with ONE sequential task, so a slow link
    serializes, it never reorders."""
    # descending sizes: were delays applied concurrently, the small late
    # frames would overtake the big early ones
    payloads = [_payload(sz, i) for i, sz in
                enumerate(((1 << 16) + 5, 4096, 512, 64, 0))]
    cfg = NetConfig(latency_s=0.01, bandwidth_bps=4e6)
    a = net.Node(0, cfg=cfg).start()
    b = net.Node(1, cfg=cfg).start(listen=False)
    try:
        b.connect(0, cfg.host, a.port)
        for i, p in enumerate(payloads):
            b.send(0, net.ENC, step=i, payload=p, phase="encode")
        got = [a.recv(net.ENC, src=1, timeout=10.0)
               for _ in range(len(payloads))]
        assert [f.step for f in got] == list(range(len(payloads)))
        assert [f.payload for f in got] == payloads
        # every send was metered into the sender's phase counters
        assert b.sent_frames["encode"] == len(payloads)
        assert b.sent_bytes["encode"] == sum(
            wire.HEADER_SIZE + len(p) for p in payloads)
    finally:
        a.stop()
        b.stop()


def test_recv_timeout_raises_nodetimeout():
    cfg = NetConfig(recv_timeout_s=0.05, recv_retries=2)
    a = net.Node(0, cfg=cfg).start()
    try:
        with pytest.raises(net.NodeTimeout, match="no SHARE frame"):
            a.recv(net.SHARE, src=3, step=0)
    finally:
        a.stop()


def test_stale_step_frames_are_dropped():
    """A slow peer's frame for a PAST step must not satisfy a later
    step's recv (the elastic-decode staleness rule)."""
    cfg = NetConfig(recv_timeout_s=0.2, recv_retries=1)
    a = net.Node(0, cfg=cfg).start()
    b = net.Node(1, cfg=cfg).start(listen=False)
    try:
        b.connect(0, cfg.host, a.port)
        b.send(0, net.SHARE, step=0, payload=b"late")
        b.send(0, net.SHARE, step=2, payload=b"fresh")
        got = a.recv(net.SHARE, src=1, step=2, timeout=5.0)
        assert got.payload == b"fresh"
        with pytest.raises(net.NodeTimeout):
            a.recv(net.SHARE, src=1, step=2, timeout=0.05, retries=1)
    finally:
        a.stop()
        b.stop()


def test_dropped_frames_counted_receiver_side():
    """Every stale-step frame discarded by recv shows up in the
    receiver's `dropped_frames` (keyed by kind name), while the sender's
    per-phase sent counters are untouched -- so the static frame budget
    stays exact on degraded runs."""
    cfg = NetConfig(recv_timeout_s=0.2, recv_retries=1)
    a = net.Node(0, cfg=cfg).start()
    b = net.Node(1, cfg=cfg).start(listen=False)
    try:
        b.connect(0, cfg.host, a.port)
        b.send(0, net.SHARE, step=0, payload=b"late0", phase="exchange")
        b.send(0, net.SHARE, step=1, payload=b"late1", phase="exchange")
        b.send(0, net.SHARE, step=2, payload=b"fresh", phase="exchange")
        got = a.recv(net.SHARE, src=1, step=2, timeout=5.0)
        assert got.payload == b"fresh"
        assert a.dropped_frames == {"SHARE": 2}
        assert a.stats()["dropped"] == {"SHARE": 2}
        # drops are a receiver-side observation only
        assert b.dropped_frames == {}
        assert b.sent_frames["exchange"] == 3  # dropped frames still sent
    finally:
        a.stop()
        b.stop()


def test_recv_any_counts_stale_drops():
    """recv_any's stale purge increments the same drop counter."""
    cfg = NetConfig(recv_timeout_s=0.2, recv_retries=1)
    a = net.Node(0, cfg=cfg).start()
    b = net.Node(1, cfg=cfg).start(listen=False)
    try:
        b.connect(0, cfg.host, a.port)
        b.send(0, net.SHARE, step=0, payload=b"old")
        b.send(0, net.SHARE, step=3, payload=b"new")
        deadline = time.monotonic() + 5.0
        frm = None
        while frm is None and time.monotonic() < deadline:
            frm = a.recv_any(net.SHARE, 3, timeout=0.05)
        assert frm is not None and frm.payload == b"new"
        assert a.dropped_frames == {"SHARE": 1}
    finally:
        a.stop()
        b.stop()
