"""seclint conformance: fixture corpus, self-run gate, corruption drills.

Three layers, mirroring how the analyzer is used:

* fixture corpus (tests/fixtures/seclint/): one known-bad and one
  known-good snippet per rule ID, with EXACT expected active-rule sets --
  a rule that stops firing (or starts over-firing) fails here first;
* the live gate: `repro.analysis` over all of src/repro must be clean and
  finish well inside the CI budget;
* corruption drills: deliberately breaking core/protocol.py (opening a
  share outside a sanctioned sink; dropping a `% field.P` before an int32
  narrow) must flip the CLI to a non-zero exit with the right rule ID.
"""

import os
import subprocess
import sys
import tempfile
import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import analyze_paths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_REPRO = os.path.join(REPO, "src", "repro")
FIXTURES = os.path.join(REPO, "tests", "fixtures", "seclint")


def _active_rules(result):
    return sorted({f.rule for f in result.active})


def _run_cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *argv],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=120)


# ------------------------------------------------------------- fixture corpus

CORPUS = [
    ("sec001_bad.py", ["SEC001"]),
    ("sec001_good.py", []),
    ("sec002_bad.py", ["SEC002"]),
    ("sec002_good.py", []),
    ("sec003_bad.py", ["SEC003"]),
    ("sec003_good.py", []),
    ("procsend_bad.py", ["SEC001"]),  # hand-rolled socket write of a Share
    ("procsend_good.py", []),         # via the sanctioned wire.share_payload
    ("servesend_bad.py", ["SEC001"]),  # raw model-share bytes on the wire
    ("servesend_good.py", []),         # only logits open (coded.open_logits)
    ("fld001_bad.py", ["FLD001"]),
    ("fld001_good.py", []),
    ("fld002_bad.py", ["FLD002"]),
    ("fld002_good.py", []),
    ("fld003_bad.py", ["FLD003"]),
    ("fld003_good.py", []),
    ("fld004_bad.py", ["FLD004"]),
    ("fld004_good.py", []),
    ("barrett_bad.py", ["FLD001", "FLD002"]),  # lazy accum, no reduce site
    ("barrett_good.py", []),   # barrett_reduce/fold26 sanction the subtree
    ("wvr001_bad.py", ["SEC001", "WVR001"]),  # malformed pragma waives nothing
    ("wvr001_good.py", []),                   # both findings waived
    ("wvr002_strict.py", []),                 # unused waiver: clean by default
]


@pytest.mark.parametrize("name,expected", CORPUS,
                         ids=[c[0].removesuffix(".py") for c in CORPUS])
def test_fixture_corpus(name, expected):
    res = analyze_paths([os.path.join(FIXTURES, name)])
    assert _active_rules(res) == expected


def test_waived_findings_recorded_with_reasons():
    res = analyze_paths([os.path.join(FIXTURES, "wvr001_good.py")])
    assert res.active == []
    waived = res.waived
    assert len(waived) == 2
    assert all(f.rule == "SEC001" and f.waiver_reason for f in waived)


def test_strict_surfaces_unused_waiver():
    path = os.path.join(FIXTURES, "wvr002_strict.py")
    assert _active_rules(analyze_paths([path])) == []
    strict = analyze_paths([path], strict=True)
    assert "WVR002" in _active_rules(strict)


# --------------------------------------------------------------- the live gate

def test_self_run_clean_and_fast():
    """The committed tree carries zero unexplained findings, and the gate
    fits in the CI fast lane (<30 s; typically well under 1 s)."""
    t0 = time.monotonic()
    res = analyze_paths([SRC_REPRO])
    elapsed = time.monotonic() - t0
    assert elapsed < 30.0, f"seclint took {elapsed:.1f}s (budget 30s)"
    assert res.active == [], "\n".join(
        f"{f.location} {f.rule} {f.message}" for f in res.active)


def test_cli_exit_codes():
    ok = _run_cli(os.path.join(FIXTURES, "sec001_good.py"))
    assert ok.returncode == 0, ok.stdout + ok.stderr
    bad = _run_cli(os.path.join(FIXTURES, "sec001_bad.py"))
    assert bad.returncode == 1
    assert "SEC001" in bad.stdout
    waived = _run_cli(os.path.join(FIXTURES, "wvr001_good.py"))
    assert waived.returncode == 0
    strict = _run_cli("--strict", os.path.join(FIXTURES, "wvr001_good.py"))
    assert strict.returncode == 1  # strict treats waivers as errors


def test_budget_report_lists_waivers():
    out = _run_cli("--budget-report", "-",
                   os.path.join(FIXTURES, "wvr001_good.py"))
    assert out.returncode == 0
    assert "allow[SEC001]" in out.stdout
    assert "trailing-style waiver" in out.stdout


# ---------------------------------------------------------- corruption drills

def _protocol_source():
    with open(os.path.join(SRC_REPRO, "core", "protocol.py")) as fh:
        return fh.read()


def _analyze_corrupted(source):
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "protocol.py")
        with open(path, "w") as fh:
            fh.write(source)
        return _run_cli("--package", "repro.core", path)


def test_corrupted_protocol_share_leak_is_flagged():
    """Opening w_shares via print() inside decode_and_update -> SEC001."""
    src = _protocol_source()
    anchor = "xtg_shares = jax.vmap("
    assert anchor in src, "protocol.py changed; update the corruption drill"
    bad = src.replace(
        anchor, "print(state.w_shares)\n        " + anchor, 1)
    proc = _analyze_corrupted(bad)
    assert proc.returncode == 1
    assert "SEC001" in proc.stdout


def test_corrupted_protocol_dropped_reduction_is_flagged():
    """Removing the `% field.P` before the int32 narrow in _decode_vec
    -> FLD002."""
    src = _protocol_source()
    anchor = "(dmat.sum(axis=0) % field.P).astype(np.int32)"
    assert anchor in src, "protocol.py changed; update the corruption drill"
    bad = src.replace(anchor, "dmat.sum(axis=0).astype(np.int32)", 1)
    proc = _analyze_corrupted(bad)
    assert proc.returncode == 1
    assert "FLD002" in proc.stdout


def test_uncorrupted_protocol_copy_is_clean():
    """The drill harness itself must not produce findings on the pristine
    file (otherwise the corruption assertions prove nothing)."""
    proc = _analyze_corrupted(_protocol_source())
    assert proc.returncode == 0, proc.stdout + proc.stderr


# ------------------------------------------------------------ property: FLD

_PROP_TEMPLATE = """from repro.core import field


def f(x, y):
    z = field.mul(x, y)
    return ({expr}).astype("int32")
"""


@given(st.sampled_from(["+", "-", "*"]), st.integers(1, 4096),
       st.integers(1, 3))
@settings(max_examples=12, deadline=None)
def test_random_unreduced_field_expression_is_flagged(op, k, depth):
    """Any raw-arithmetic chain over a field value, narrowed without a
    dominating `% field.P`, must trip both the raw-op and the
    unreduced-narrow rules."""
    expr = "z"
    for _ in range(depth):
        expr = f"({expr} {op} {k})"
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "snippet.py")
        with open(path, "w") as fh:
            fh.write(_PROP_TEMPLATE.format(expr=expr))
        rules = _active_rules(analyze_paths([path]))
    assert "FLD001" in rules and "FLD002" in rules, (expr, rules)
