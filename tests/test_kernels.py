"""Per-kernel sweeps: Pallas (interpret=True) vs pure-jnp ref vs uint64."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import field as F
from repro.kernels import coded_gradient as cgk
from repro.kernels import field_poly as fpk
from repro.kernels import modmatmul as mmk
from repro.kernels import ops, ref


@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8), (128, 512, 128), (128, 1024, 128), (64, 2048, 32),
    (256, 300, 48),   # padding path
])
def test_modmatmul_shapes(rng, m, k, n):
    a = jnp.asarray(rng.integers(0, F.P, size=(m, k)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(k, n)).astype(np.int32))
    got = ops.modmatmul(a, b, force_pallas=True)
    assert got.shape == (m, n)          # exact shape, padding sliced off
    np.testing.assert_array_equal(
        np.asarray(got), F.np_matmul(np.asarray(a), np.asarray(b)))
    np.testing.assert_array_equal(
        np.asarray(ref.modmatmul(a, b)),
        F.np_matmul(np.asarray(a), np.asarray(b)))
    assert ops.modmatmul_exact is ops.modmatmul   # historical alias


@given(st.integers(1, 40), st.integers(1, 50), st.integers(1, 30),
       st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None)
def test_modmatmul_hypothesis(m, k, n, seed):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.integers(0, F.P, size=(m, k)).astype(np.int32))
    b = jnp.asarray(r.integers(0, F.P, size=(k, n)).astype(np.int32))
    got = ops.modmatmul_exact(a, b, force_pallas=True, bm=16, bn=16,
                              bk=32)
    np.testing.assert_array_equal(
        np.asarray(got), F.np_matmul(np.asarray(a), np.asarray(b)))


def test_modmatmul_extreme(rng):
    a = jnp.full((16, 1024), F.P - 1, jnp.int32)
    b = jnp.full((1024, 16), F.P - 1, jnp.int32)
    got = ops.modmatmul_exact(a, b, force_pallas=True)
    np.testing.assert_array_equal(
        np.asarray(got), F.np_matmul(np.asarray(a), np.asarray(b)))


@pytest.mark.parametrize("size,degree", [(64, 1), (4096, 1), (5000, 3),
                                         (1, 2)])
def test_poly_eval_kernel(rng, size, degree):
    z = jnp.asarray(rng.integers(0, F.P, size=size).astype(np.int32))
    c = jnp.asarray(rng.integers(0, F.P, size=degree + 1).astype(np.int32))
    got = ops.poly_eval(z, c, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(ref.poly_eval(z, c)))


@pytest.mark.parametrize("m,d,r", [(8, 8, 1), (256, 130, 1), (100, 600, 3),
                                   (512, 512, 1)])
def test_coded_gradient_fused(rng, m, d, r):
    x = jnp.asarray(rng.integers(0, F.P, size=(m, d)).astype(np.int32))
    w = jnp.asarray(rng.integers(0, F.P, size=(d,)).astype(np.int32))
    c = jnp.asarray(rng.integers(0, F.P, size=(r + 1,)).astype(np.int32))
    got = ops.coded_gradient(x, w, c, force_pallas=True)
    exp = ref.coded_gradient(x, w, c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    # independent uint64 oracle for the same composite
    z = F.np_matmul(np.asarray(x), np.asarray(w)[:, None])[:, 0]
    g = np.zeros_like(z)
    for ci in reversed(np.asarray(c).astype(np.int64)):
        g = (g * z + ci) % F.P
    exp2 = F.np_matmul(np.asarray(x).T, g[:, None].astype(np.int32))[:, 0]
    np.testing.assert_array_equal(np.asarray(got), exp2)


@pytest.mark.parametrize("nb,m,d,r", [(8, 64, 32, 1), (5, 96, 40, 3)])
def test_coded_gradient_batched_matches_vmap(rng, nb, m, d, r):
    """Batched engines == per-client vmap of the single-client kernel,
    element-for-element mod p (second case exercises the padding path)."""
    x = jnp.asarray(rng.integers(0, F.P, size=(nb, m, d)).astype(np.int32))
    w = jnp.asarray(rng.integers(0, F.P, size=(nb, d)).astype(np.int32))
    c = jnp.asarray(rng.integers(0, F.P, size=(r + 1,)).astype(np.int32))
    expected = np.asarray(jax.vmap(
        lambda xi, wi: ops.coded_gradient(xi, wi, c, force_pallas=True,
                                          bm=32, dc=16))(x, w))
    # jnp reference path (limb-packed batched GEMM)
    np.testing.assert_array_equal(
        np.asarray(ref.coded_gradient_batched(x, w, c)), expected)
    np.testing.assert_array_equal(
        np.asarray(ref.coded_gradient_vmap(x, w, c)), expected)
    # batched-grid Pallas kernel (interpret)
    got = ops.coded_gradient_batched(x, w, c, force_pallas=True,
                                     bm=32, dc=16)
    np.testing.assert_array_equal(np.asarray(got), expected)


@pytest.mark.parametrize("bsz,m,k,n", [(4, 32, 48, 24), (3, 30, 70, 18)])
def test_modmatmul_batched_matches_vmap(rng, bsz, m, k, n):
    a = jnp.asarray(rng.integers(0, F.P, size=(bsz, m, k)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(bsz, k, n)).astype(np.int32))
    expected = np.stack([F.np_matmul(np.asarray(a[i]), np.asarray(b[i]))
                         for i in range(bsz)])
    got = ops.modmatmul_batched(a, b, force_pallas=True, bm=16, bn=16, bk=32)
    assert got.shape == (bsz, m, n)
    np.testing.assert_array_equal(np.asarray(got), expected)
    np.testing.assert_array_equal(
        np.asarray(ref.modmatmul_batched(a, b)), expected)
    vmapped = np.asarray(jax.vmap(
        lambda ai, bi: ops.modmatmul(ai, bi, force_pallas=True,
                                     bm=16, bn=16, bk=32))(a, b))
    np.testing.assert_array_equal(vmapped, expected)


def test_matvec_batched_extreme(rng):
    """All-(p-1) operands through the limb-packed batched GEMM."""
    a = jnp.full((3, 8, F.MATMUL_CHUNK + 5), F.P - 1, jnp.int32)
    v = jnp.full((3, F.MATMUL_CHUNK + 5), F.P - 1, jnp.int32)
    got = np.asarray(F.matvec_batched(a, v))
    exp = F.np_matmul(np.asarray(a[0]), np.asarray(v[0])[:, None])[:, 0]
    for i in range(3):
        np.testing.assert_array_equal(got[i], exp)


def test_block_shape_sweep(rng):
    """VMEM tiling choices must not change results."""
    x = jnp.asarray(rng.integers(0, F.P, size=(96, 160)).astype(np.int32))
    w = jnp.asarray(rng.integers(0, F.P, size=(160,)).astype(np.int32))
    c = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    expected = np.asarray(ref.coded_gradient(x, w, c))
    # NOTE: tiny blocks (8,8) mean thousands of interpret-mode grid steps
    # (~minutes per combo on CPU); two contrasting tilings cover the
    # index-map/accumulator logic just as well.
    for bm, dc in ((32, 32), (96, 160)):
        got = ops.coded_gradient(x, w, c, force_pallas=True, bm=bm, dc=dc)
        np.testing.assert_array_equal(np.asarray(got), expected)
