"""Fault-injection engine: FaultPlan schedules honored by every engine.

The acceptance properties of the resilience subsystem:

* a jit-engine COPML run with a mid-training straggler/dropout/adversary
  schedule is BIT-EXACT with the eager engine replaying the same
  FaultPlan -- and with the fault-free baseline (decoding from any valid
  R-subset yields the identical field element: zero recovery cost);
* a plan that ever drops below the recovery threshold raises the named
  FaultPlanViolation before any compute;
* adversarial contributions are corrupted for real in-graph, so the
  bit-exactness above proves the decode actually excludes them;
* the conformance grid: every registered protocol x {eager, jit} trains
  the smoke workload to a pinned minimum accuracy with finite history --
  the divergence catcher the bit-exact goldens cannot be.
"""

import numpy as np
import pytest

from repro import api
from repro.api.faults import FaultPlan, FaultPlanViolation
from repro.train import elastic

# smoke_straggler: N=13, K=3, T=1 -> R = 3*(3+1-1)+1 = 10, 3 clients slack
_N, _R, _ITERS = 13, 10, 6


def _plan():
    """Mid-training churn touching all three fault kinds, validated:
    min availability exactly R at step 4 (zero headroom is legal)."""
    return FaultPlan.from_schedule(
        _N, _ITERS,
        stragglers={1: (0, 1), 4: (2,)},
        dropouts={2: (7,)},
        adversaries={3: (8,)})


# ------------------------------------------------------------ plan algebra


def test_plan_masks_and_schedules():
    p = _plan()
    assert p.available.shape == (_ITERS, _N)
    # straggler misses one step only
    assert not p.available[1, 0] and p.available[2, 0]
    # dropout is permanent from its step
    assert p.available[1, 7] and not p.available[2:, 7].any()
    # adversary: unavailable AND corrupting from its step
    assert not p.available[3:, 8].any() and p.adversary[3:, 8].all()
    assert not p.adversary[:3, 8].any()
    assert p.has_adversaries and not p.is_fault_free
    np.testing.assert_array_equal(p.available_counts,
                                  [13, 11, 12, 11, 10, 11])
    np.testing.assert_array_equal(p.headroom(_R), [3, 1, 2, 1, 0, 1])
    # per-step decode subsets: first R available, adversary excluded
    subs = p.subsets(_R)
    assert len(subs) == _ITERS and all(len(s) == _R for s in subs)
    assert 8 not in subs[3] and 7 not in subs[4] and 0 not in subs[1]
    # masks are frozen
    with pytest.raises(ValueError):
        p.available[0, 0] = False


def test_plan_validation_and_builders():
    ok = _plan().validate(_R)
    assert ok.min() == 0
    with pytest.raises(FaultPlanViolation, match="below the .* threshold"):
        FaultPlan.from_schedule(_N, 4, dropouts={1: (0, 1, 2, 3)}) \
            .validate(_R)
    with pytest.raises(ValueError, match="outside"):
        FaultPlan.from_schedule(_N, 4, stragglers={9: (0,)})
    with pytest.raises(ValueError, match="outside"):
        FaultPlan.from_schedule(_N, 4, stragglers={0: (13,)})
    with pytest.raises(ValueError, match="both available and adversarial"):
        FaultPlan(_N, 2, np.ones((2, _N), bool), np.ones((2, _N), bool))
    # fault_free + slice
    ff = FaultPlan.fault_free(_N, 8)
    assert ff.is_fault_free and ff.slice(3).iters == 3
    with pytest.raises(ValueError, match="cannot[\\s\\S]*slice"):
        ff.slice(9)
    # random() with repair never violates; seeded = reproducible
    r1 = FaultPlan.random(_N, 20, seed=7, straggle_p=0.3, n_dropouts=1,
                          min_available=_R)
    r2 = FaultPlan.random(_N, 20, seed=7, straggle_p=0.3, n_dropouts=1,
                          min_available=_R)
    np.testing.assert_array_equal(r1.available, r2.available)
    r1.validate(_R)
    assert not r1.is_fault_free
    assert "FaultPlan" in r1.describe(_R)


def test_budget_helpers_power_the_validation():
    """The elastic.py budgets ARE the plan validation thresholds."""
    b = elastic.straggler_budget(_N, 3, 1)
    assert b.recovery_threshold == _R and b.tolerable == 3
    head = elastic.validate_budget([12, 10, 11], b.recovery_threshold)
    np.testing.assert_array_equal(head, [2, 0, 1])
    with pytest.raises(FaultPlanViolation, match="step 1"):
        elastic.validate_budget([12, 9, 11], b.recovery_threshold)


# ----------------------------------------------- engine acceptance (copml)


@pytest.fixture(scope="module")
def faulty_jit():
    return api.fit("smoke_straggler", "copml", "jit", key=0, iters=_ITERS,
                   faults=_plan())


def test_jit_eager_bit_exact_under_faults(faulty_jit):
    """ACCEPTANCE: jit replaying the FaultPlan == eager replaying it,
    bit-for-bit, per step."""
    res_e = api.fit("smoke_straggler", "copml", "eager", key=0,
                    iters=_ITERS, faults=_plan())
    np.testing.assert_array_equal(faulty_jit.weights, res_e.weights)
    np.testing.assert_array_equal(faulty_jit.history, res_e.history)
    np.testing.assert_array_equal(np.asarray(faulty_jit.state.w_shares),
                                  np.asarray(res_e.state.w_shares))


def test_faulty_run_bit_exact_vs_fault_free(faulty_jit):
    """Zero recovery cost, executable: the churned trajectory (stragglers,
    a dropout, AND a genuinely corrupted adversary) is the identical model
    trajectory as the fault-free full-decode run."""
    base = api.fit("smoke_straggler", "copml", "jit", key=0, iters=_ITERS,
                   subset="all")
    np.testing.assert_array_equal(faulty_jit.weights, base.weights)
    np.testing.assert_array_equal(faulty_jit.history, base.history)


@pytest.mark.slow
def test_sharded_engine_replays_plan(faulty_jit):
    """The shard_map engine threads the same per-step arrays (1-device
    mesh in-process; multi-device parity is the slow subprocess lane).
    slow: compiles a dedicated faulty shard_map scan (~40s)."""
    res_s = api.fit("smoke_straggler", "copml",
                    api.EngineSpec("sharded", devices=1), key=0,
                    iters=_ITERS, faults=_plan(), history=False)
    np.testing.assert_array_equal(res_s.weights, faulty_jit.weights)
    np.testing.assert_array_equal(np.asarray(res_s.state.w_shares),
                                  np.asarray(faulty_jit.state.w_shares))


@pytest.mark.slow
def test_adversary_inclusion_would_corrupt(faulty_jit):
    """Negative control for the corruption plumbing: decoding from a
    subset that INCLUDES the corrupted client 8 at step 3 changes the
    model -- proving test_faulty_run_bit_exact_vs_fault_free passes
    because of the exclusion, not because corruption is cosmetic.
    slow: needs its own history=False scan compile."""
    wl = api.get_workload("smoke_straggler")
    proto = api.PROTOCOLS["copml"].driver(wl)
    plan = _plan()
    subs = list(plan.subsets(_R))
    bad = tuple(sorted(set(subs[3][:_R - 1]) | {8}))   # force 8 back in
    subs[3] = bad
    import jax
    cx, cy = wl.client_data()
    _, w_bad = proto._train_jit(jax.random.PRNGKey(0), cx, cy, _ITERS,
                                step_subsets=tuple(subs),
                                adversaries=plan.adversary)
    assert not np.array_equal(np.asarray(w_bad), faulty_jit.weights)


def test_availability_record(faulty_jit):
    rec = faulty_jit.availability
    assert rec is not None and rec.shape == (_ITERS, _N) \
        and rec.dtype == bool
    np.testing.assert_array_equal(rec, _plan().available)
    assert "churn" in faulty_jit.summary()
    # fault-free runs carry no record
    assert api.fit("smoke", "float", "jit", key=0, iters=2,
                   history=False).availability is None


# ------------------------------------------------------- validation errors


def test_violating_plan_raises_before_compute(monkeypatch):
    """ACCEPTANCE: under-provisioned plan -> named error, no engine work."""
    bad = FaultPlan.from_schedule(_N, _ITERS, dropouts={2: (0, 1, 2, 3)})
    ran = []
    cls = type(api.PROTOCOLS["copml"])
    monkeypatch.setattr(cls, "_run",
                        lambda self, *a, **k: ran.append(1))
    with pytest.raises(FaultPlanViolation, match="recovery threshold"):
        api.fit("smoke_straggler", "copml", "jit", key=0, iters=_ITERS,
                faults=bad)
    assert not ran, "engine ran despite an invalid plan"


def test_fault_argument_validation():
    plan = _plan()
    with pytest.raises(ValueError, match="mutually exclusive"):
        api.fit("smoke_straggler", "copml", "jit", iters=_ITERS,
                faults=plan, subset=(0, 1))
    with pytest.raises(ValueError, match="no fault injection"):
        api.fit("smoke", "float", "jit", iters=2, faults=plan)
    with pytest.raises(TypeError, match="FaultPlan"):
        api.fit("smoke_straggler", "copml", "jit", iters=2, faults={0: 1})
    with pytest.raises(ValueError, match="clients"):
        api.fit("smoke", "copml", "jit", iters=2,
                faults=FaultPlan.fault_free(7, 2))
    with pytest.raises(ValueError, match="covers 2 steps"):
        api.fit("smoke_straggler", "copml", "jit", iters=4,
                faults=FaultPlan.fault_free(_N, 2))
    with pytest.raises(FaultPlanViolation, match="corrupted"):
        api.fit("smoke", "secure_agg", "jit", iters=2,
                faults=FaultPlan.from_schedule(_N, 2,
                                               adversaries={0: (3,)}))


# ------------------------------------------------ secure_agg share selection


def test_secure_agg_per_step_share_selection():
    """T+1-of-N per-step holder selection: churned reconstruction subsets
    reproduce the fault-free model on both engines (the sum's shares
    reconstruct from ANY T+1 holders)."""
    plan = FaultPlan.random(_N, 5, seed=3, straggle_p=0.4, n_dropouts=2,
                            min_available=4)
    plan.validate(elastic.secure_agg_budget(_N, 1).recovery_threshold)
    res_e = api.fit("smoke", "secure_agg", "eager", key=0, iters=5,
                    faults=plan)
    res_j = api.fit("smoke", "secure_agg", "jit", key=0, iters=5,
                    faults=plan)
    base = api.fit("smoke", "secure_agg", "jit", key=0, iters=5)
    np.testing.assert_allclose(res_e.weights, res_j.weights, atol=1e-5)
    np.testing.assert_allclose(res_j.weights, base.weights, atol=1e-5)
    np.testing.assert_array_equal(res_j.availability, plan.available)


# --------------------------------------------- cross-protocol conformance


@pytest.mark.parametrize("protocol", ["copml", "mpc_baseline", "float",
                                      "poly_float", "secure_agg"])
@pytest.mark.parametrize("engine", ["eager", "jit"])
def test_conformance_grid_accuracy_and_finiteness(protocol, engine):
    """Every protocol x engine LEARNS on smoke (pinned minimum accuracy)
    and produces finite history -- catches silent divergence (NaN/inf or
    a non-training update rule) that schema checks and bit-exact goldens
    against a frozen reference cannot."""
    res = api.fit("smoke", protocol, engine, key=0, iters=5)
    assert np.isfinite(res.history).all(), "non-finite model trajectory"
    assert np.isfinite(res.weights).all()
    # every protocol reaches 0.75 on this separable task by iter 5
    # (measured floor across the grid is 0.792; see PR notes)
    assert res.final_accuracy >= 0.75, (
        f"{protocol}/{engine} accuracy {res.final_accuracy} below pin")
    # and the curve must actually move or start high: no dead training
    assert res.accuracy.max() >= 0.75
