"""End-to-end COPML: accuracy parity, straggler equivalence, Thm-1 bound."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sigmoid_approx
from repro.core.baselines import float_logreg, float_poly_logreg, sigmoid
from repro.core.protocol import (Copml, CopmlConfig, case1_params,
                                 case2_params)
from repro.data import pipeline


@pytest.fixture(scope="module")
def task():
    x, y = pipeline.classification_dataset(m=208, d=12, seed=1, margin=2.0)
    return x, y


def _acc(x, y, w):
    return float(((sigmoid(x @ np.asarray(w, np.float64)) > .5) == y).mean())


@pytest.fixture(scope="module")
def trained(task):
    x, y = task
    n = 13
    k, t = case1_params(n)
    cfg = CopmlConfig(n_clients=n, k=k, t=t, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1])
    cx, cy = pipeline.split_clients(x, y, n)
    state, w = proto.train(jax.random.PRNGKey(0), cx, cy, iters=30)
    return proto, state, np.asarray(w), x, y


def test_accuracy_parity_with_float(trained):
    """Fig. 4: COPML within a few points of conventional logistic reg."""
    proto, state, w, x, y = trained
    wf = float_logreg(x, y, eta=1.0, iters=30)
    acc_f, acc_c = _acc(x, y, wf), _acc(x, y, w)
    assert acc_f > 0.75                       # task is learnable
    assert acc_c > acc_f - 0.08, (acc_c, acc_f)


def test_polynomial_approx_not_the_bottleneck(task):
    """r=1 float-poly logreg ~ float logreg (paper: degree one suffices)."""
    x, y = task
    wf = float_logreg(x, y, 1.0, 30)
    wp = float_poly_logreg(x, y, 1.0, 30, r=1)
    assert _acc(x, y, wp) > _acc(x, y, wf) - 0.05


def test_straggler_subsets_give_identical_model(task):
    """Decoding from ANY R of N clients yields the same training run --
    the recovery-threshold property at the full-protocol level."""
    x, y = task
    n = 13
    k, t = case1_params(n)             # K=4, T=1 -> R = 13
    # leave slack: use K=3 so R = 3*3+1 = 10 < 13
    cfg = CopmlConfig(n_clients=n, k=3, t=1, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1])
    cx, cy = pipeline.split_clients(x, y, n)
    r = cfg.recovery_threshold
    _, w_first = proto.train(jax.random.PRNGKey(0), cx, cy, iters=4,
                             subset=tuple(range(r)))
    _, w_last = proto.train(jax.random.PRNGKey(0), cx, cy, iters=4,
                            subset=tuple(range(n - r, n)))
    np.testing.assert_array_equal(np.asarray(w_first), np.asarray(w_last))


def test_convergence_bound_thm1(task):
    """Empirical suboptimality obeys  C(w_bar) - C(w*) <=
    ||w0-w*||^2/(2 eta J) + eta sigma^2  (Theorem 1)."""
    x, y = task
    m, d = x.shape
    n = 13
    cfg = CopmlConfig(n_clients=n, k=3, t=1, eta=0.5)
    proto = Copml(cfg, m, d)
    cx, cy = pipeline.split_clients(x, y, n)
    ws = []
    state, w = proto.train(jax.random.PRNGKey(0), cx, cy, iters=20,
                           callback=lambda t, w: ws.append(np.asarray(w)))

    def cost(w):
        z = np.clip(x @ w, -30, 30)
        p = sigmoid(z)
        eps = 1e-9
        return float(np.mean(-y * np.log(p + eps)
                             - (1 - y) * np.log(1 - p + eps)))

    w_star = float_logreg(x, y, 0.5, 3000)
    w_bar = np.mean(ws, axis=0)
    j = len(ws)
    eta = cfg.eta
    sigma2 = d * 4 ** 2 / m ** 2     # paper's sigma in *model-grid* units:
    # after truncation the noise lives on the 2^-lw grid; use the empirical
    # form d * (2^-lw)^2 / 4 as the per-step variance bound
    sigma2 = d * (2.0 ** -cfg.lw) ** 2 / 4
    bound = (np.linalg.norm(w_star) ** 2) / (2 * eta * j) + eta * sigma2
    sub = cost(w_bar) - cost(w_star)
    # the bound holds with slack (it is loose); check the right order
    assert sub <= bound * 3 + 0.1, (sub, bound)


def test_train_jit_matches_eager_bit_exact():
    """The lax.scan engine reproduces the eager per-step loop bit-exactly:
    same final shares, same opened model, same per-step trajectory."""
    x, y = pipeline.classification_dataset(m=70, d=6, seed=4, margin=2.0)
    n = 7
    cfg = CopmlConfig(n_clients=n, k=2, t=1, eta=1.0)   # R = 3*2+1 = 7
    proto = Copml(cfg, x.shape[0], x.shape[1])
    cx, cy = pipeline.split_clients(x, y, n)
    key = jax.random.PRNGKey(11)

    eager_hist = []
    st_e, w_e = proto.train_eager(
        key, cx, cy, iters=5,
        callback=lambda t, w: eager_hist.append(np.asarray(w)))
    st_j, w_j, hist = proto.train_jit(key, cx, cy, iters=5, history=True)

    np.testing.assert_array_equal(np.asarray(w_e), np.asarray(w_j))
    np.testing.assert_array_equal(np.asarray(st_e.w_shares),
                                  np.asarray(st_j.w_shares))
    assert hist.shape[0] == 5
    for t in range(5):
        np.testing.assert_array_equal(eager_hist[t], np.asarray(hist[t]))
    assert int(st_j.step) == 5


def test_train_jit_single_compiled_step(monkeypatch):
    """The scan engine traces the iteration exactly once for the whole run
    (vs once-per-step dispatch in the eager loop)."""
    from repro.core import protocol as proto_mod
    x, y = pipeline.classification_dataset(m=70, d=6, seed=4, margin=2.0)
    cfg = CopmlConfig(n_clients=7, k=2, t=1, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1])   # fresh instance => fresh trace
    cx, cy = pipeline.split_clients(x, y, 7)

    calls = {"n": 0}
    orig = proto_mod.Copml.iteration

    def counted(self, key, state, subset=None):
        calls["n"] += 1
        return orig(self, key, state, subset)

    monkeypatch.setattr(proto_mod.Copml, "iteration", counted)
    proto.train_jit(jax.random.PRNGKey(0), cx, cy, iters=6)
    assert calls["n"] == 1


def test_train_callback_replays_scan_history():
    """Public train(): callback fires once per step with the opened model."""
    x, y = pipeline.classification_dataset(m=70, d=6, seed=4, margin=2.0)
    cfg = CopmlConfig(n_clients=7, k=2, t=1, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1])
    cx, cy = pipeline.split_clients(x, y, 7)
    seen = []
    _, w = proto.train(jax.random.PRNGKey(2), cx, cy, iters=3,
                       callback=lambda t, wt: seen.append((t, np.asarray(wt))))
    assert [t for t, _ in seen] == [0, 1, 2]
    np.testing.assert_array_equal(seen[-1][1], np.asarray(w))


def test_case_parameterizations():
    for n in (13, 25, 50):
        k1, t1 = case1_params(n)
        assert 3 * (k1 + t1 - 1) + 1 <= n and t1 == 1
        k2, t2 = case2_params(n)
        assert 3 * (k2 + t2 - 1) + 1 <= n
        assert t2 >= max(1, (n - 3) // 6)


def test_case2_params_general_r():
    """case2_params no longer silently applies its r=1 formula for r>1:
    the general form honors (2r+1)(K+T-1)+1 <= N for every r, reduces
    exactly to the published r=1 formula, and raises (instead of
    returning an invalid split) when N is too small."""
    # r=1: bit-identical to the published formula (paper-table shapes
    # like cifar10_case2's (10, 7) at N=50 must not move)
    for n in range(7, 60):
        t_pub = max(1, (n - 3) // 6)
        k_pub = max(1, (n + 2) // 3 - t_pub)
        assert case2_params(n, 1) == (k_pub, t_pub), n
    assert case2_params(50, 1) == (10, 7)
    # general r: the recovery threshold constraint holds and the split
    # stays roughly equal (T about half the K+T budget)
    for r in (2, 3, 5):
        for n in (4 * r + 4, 25, 50, 111):
            k, t = case2_params(n, r)
            assert (2 * r + 1) * (k + t - 1) + 1 <= n, (n, r, k, t)
            assert k >= 1 and t >= 1
    # too-small N: a named error, not a silently invalid (K, T)
    with pytest.raises(ValueError, match="no valid"):
        case2_params(3, 1)
    with pytest.raises(ValueError, match="no valid"):
        case2_params(5, 2)            # even K=T=1 needs N >= 2r+2 = 6
    with pytest.raises(ValueError, match="r must be >= 1"):
        case2_params(13, 0)


def test_sigmoid_poly_quality():
    assert sigmoid_approx.max_abs_error(1) < 0.25
    assert sigmoid_approx.max_abs_error(3) < sigmoid_approx.max_abs_error(1)


def test_model_stays_secret_shared(trained):
    """No single client's share equals the model: during training clients
    hold shares only (information-theoretic privacy of the trajectory)."""
    proto, state, w, x, y = trained
    w_field = np.asarray(proto.open_model(state))
    for i in range(proto.cfg.n_clients):
        share_i = np.asarray(state.w_shares[i])
        # a share is a uniform-looking field element, not the model
        assert not np.array_equal(share_i, w_field)
