#!/usr/bin/env python
"""Diff fresh BENCH_<stage>.json trajectories against committed baselines.

CI generates fresh trajectories for the fast stages on every PR
(`python -m benchmarks.run --stage engine,multiclass --json .`); the
committed reference numbers live in benchmarks/baselines/.  This script
pairs the two by row name and fails (exit 1) when any row's wall time
regresses by more than --threshold (default 20%).

Rows are matched on their fully-qualified benchmark name
("kernel_micro/copml_train_jit_20it", ...).  A row present in the
baseline but missing from the fresh run is a failure too -- silently
dropping a benchmark is how regressions hide.  New rows (fresh-only) are
reported but do not fail: they become gated once their baseline is
committed.

Usage:
    python scripts/bench_diff.py --fresh-dir . \
        [--baseline-dir benchmarks/baselines] [--threshold 0.20] \
        [--stages engine,multiclass]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys


def load_rows(path: str) -> dict:
    """name -> us_per_call for one BENCH_<stage>.json trajectory."""
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("failure"):
        raise SystemExit(f"{path}: recorded failure: {doc['failure']}")
    return {r["name"]: float(r["us_per_call"]) for r in doc.get("rows", [])}


def diff_stage(stage: str, base_path: str, fresh_path: str,
               threshold: float) -> list:
    """Returns a list of failure strings (empty = stage passes)."""
    base = load_rows(base_path)
    fresh = load_rows(fresh_path)
    failures = []
    print(f"--- {stage}: {len(base)} baseline rows, {len(fresh)} fresh ---")
    for name, b_us in sorted(base.items()):
        if name not in fresh:
            failures.append(f"{stage}: row {name!r} missing from fresh run")
            print(f"  MISSING  {name}")
            continue
        f_us = fresh[name]
        if b_us <= 0.0:
            # ratio/derived-only rows carry no wall time; nothing to gate
            print(f"     n/a   {name}  (derived-only row, ungated)")
            continue
        ratio = f_us / b_us
        verdict = "ok"
        if ratio > 1.0 + threshold:
            verdict = f"REGRESSED >{threshold:.0%}"
            failures.append(
                f"{stage}: {name} regressed {ratio - 1.0:+.1%} "
                f"({b_us / 1e3:.2f}ms -> {f_us / 1e3:.2f}ms)")
        print(f"  {ratio - 1.0:+7.1%}  {name}  "
              f"({b_us / 1e3:.2f}ms -> {f_us / 1e3:.2f}ms)  {verdict}")
    for name in sorted(set(fresh) - set(base)):
        print(f"  NEW      {name} ({fresh[name] / 1e3:.2f}ms, ungated)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default="benchmarks/baselines",
                    help="directory with committed BENCH_<stage>.json files")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory with freshly generated trajectories")
    ap.add_argument("--threshold", type=float, default=0.20,
                    help="max tolerated wall-time growth (0.20 = +20%%)")
    ap.add_argument("--stages", default="",
                    help="comma-separated stage subset (default: every "
                         "stage with a committed baseline)")
    args = ap.parse_args(argv)

    pattern = os.path.join(args.baseline_dir, "BENCH_*.json")
    baselines = sorted(glob.glob(pattern))
    if not baselines:
        print(f"bench_diff: no baselines under {args.baseline_dir}",
              file=sys.stderr)
        return 1
    wanted = {s.strip() for s in args.stages.split(",") if s.strip()}

    failures = []
    compared = 0
    for base_path in baselines:
        stage = os.path.basename(base_path)[len("BENCH_"):-len(".json")]
        if wanted and stage not in wanted:
            continue
        fresh_path = os.path.join(args.fresh_dir, f"BENCH_{stage}.json")
        if not os.path.exists(fresh_path):
            failures.append(f"{stage}: fresh trajectory {fresh_path} "
                            "not found")
            continue
        failures += diff_stage(stage, base_path, fresh_path, args.threshold)
        compared += 1

    if wanted and compared < len(wanted):
        missing = wanted - {os.path.basename(p)[len("BENCH_"):-len(".json")]
                            for p in baselines}
        for stage in sorted(missing):
            failures.append(f"{stage}: no committed baseline "
                            f"(benchmarks/baselines/BENCH_{stage}.json)")

    if failures:
        print("\nbench_diff: FAIL")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"\nbench_diff: OK ({compared} stage(s) within "
          f"+{args.threshold:.0%})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
