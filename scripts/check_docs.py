#!/usr/bin/env python
"""Docs lint: keep README/ARCHITECTURE honest as the codebase grows.

Checks (run standalone or via tests/test_docs.py in the fast pytest lane):

1. every package under src/repro/ is mentioned in README.md or
   docs/ARCHITECTURE.md (a new subsystem must at least be named);
2. every relative markdown link in README.md and docs/*.md resolves to an
   existing file (anchors are checked for same-file heading existence);
3. the commands shown in README's Verify section reference real files;
4. docs/API.md covers the live repro.api registries: every registered
   protocol, engine, workload, and objective name and every TrainResult
   field must appear there (imports the package, so a stale doc fails the
   lint), plus the serving surface (api.serve / SERVE_ENGINES /
   SecureServer fields and the open_logits sink);
5. docs/ANALYSIS.md covers the live analyzer rule registry: every rule
   ID in repro.analysis.RULES (seclint's SEC/FLD/WVR and commlint's COM
   families) must appear in the catalog;
6. docs/ARCHITECTURE.md's wire-protocol round table covers the live
   choreography spec: every frame kind in
   repro.analysis.choreography.KINDS must appear there.

Exit code 0 = clean; 1 = problems (each printed on its own line).
"""

from __future__ import annotations

import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f) for f in os.listdir(os.path.join(ROOT, "docs"))
    if f.endswith(".md"))

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def _anchor(heading: str) -> str:
    """GitHub-style anchor: lowercase, strip punctuation, spaces->dashes."""
    h = re.sub(r"[`*_]", "", heading.strip().lower())
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def check_packages(doc_text: str) -> list:
    """Every src/repro/* package directory must be named in the docs."""
    problems = []
    pkg_root = os.path.join(ROOT, "src", "repro")
    for name in sorted(os.listdir(pkg_root)):
        path = os.path.join(pkg_root, name)
        if not os.path.isdir(path) or name.startswith("__"):
            continue
        if not any(os.path.splitext(f)[1] == ".py" for f in os.listdir(path)):
            continue
        if f"repro/{name}" not in doc_text and f"`{name}/" not in doc_text \
                and f"src/repro/{name}" not in doc_text:
            problems.append(
                f"package src/repro/{name} is not mentioned in README.md or "
                f"docs/ARCHITECTURE.md")
    return problems


def check_links() -> list:
    problems = []
    for rel in DOC_FILES:
        path = os.path.join(ROOT, rel)
        with open(path) as f:
            text = f.read()
        headings = {_anchor(h) for h in _HEADING.findall(text)}
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            file_part, _, frag = target.partition("#")
            if not file_part:                      # same-file anchor
                if frag and _anchor(frag) not in headings:
                    problems.append(f"{rel}: broken anchor #{frag}")
                continue
            resolved = os.path.normpath(
                os.path.join(ROOT, os.path.dirname(rel), file_part))
            if not os.path.exists(resolved):
                problems.append(f"{rel}: broken link {target}")
            elif frag and resolved.endswith(".md"):
                with open(resolved) as f:
                    t_head = {_anchor(h) for h in _HEADING.findall(f.read())}
                if _anchor(frag) not in t_head:
                    problems.append(f"{rel}: broken anchor {target}")
    return problems


def check_commands() -> list:
    problems = []
    with open(os.path.join(ROOT, "README.md")) as f:
        readme = f.read()
    for needed in ("examples/quickstart.py", "scripts/check_docs.py",
                   "benchmarks"):
        if needed in readme and not os.path.exists(
                os.path.join(ROOT, needed)):
            problems.append(f"README.md references missing path {needed}")
    return problems


def check_api() -> list:
    """docs/API.md must document the LIVE api registries."""
    path = os.path.join(ROOT, "docs", "API.md")
    if not os.path.exists(path):
        return ["missing docs/API.md (the repro.api reference)"]
    with open(path) as f:
        text = f.read()
    src = os.path.join(ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    try:
        import dataclasses

        from repro import api
    except Exception as e:  # noqa: BLE001 -- an unimportable api IS a finding
        return [f"repro.api failed to import for the docs lint: {e!r}"]
    problems = []
    names = (
        [("protocol", n) for n in api.protocol_names()]
        # the LIVE kind registry (api.ENGINES is a frozen snapshot of the
        # builtins): an engine registered later must be documented too
        + [("engine", n) for n in api.engine_names()]
        + [("workload", n) for n in api.workload_names()]
        + [("objective", n) for n in api.objective_names()]
        + [("TrainResult field", f.name)
           for f in dataclasses.fields(api.TrainResult)]
        + [("fault-injection name", n)
           for n in ("FaultPlan", "FaultPlanViolation")])
    for kind, name in names:
        if f"`{name}`" not in text:
            problems.append(f"docs/API.md: {kind} `{name}` is registered "
                            f"but undocumented")
    # the fit(faults=...) parameter itself must be shown (not just the class)
    if "faults=" not in text:
        problems.append("docs/API.md: api.fit's `faults=` parameter is "
                        "undocumented")
    return problems


def check_serve() -> list:
    """docs/API.md must document the LIVE serving surface: the api names,
    the engine kinds, and every SecureServer dataclass field."""
    path = os.path.join(ROOT, "docs", "API.md")
    if not os.path.exists(path):
        return ["missing docs/API.md (the repro.api reference)"]
    with open(path) as f:
        text = f.read()
    src = os.path.join(ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    try:
        import dataclasses

        from repro import api
        from repro.serve.server import SecureServer
    except Exception as e:  # noqa: BLE001 -- an unimportable serve IS a finding
        return [f"repro.serve failed to import for the docs lint: {e!r}"]
    problems = []
    names = (
        [("serve name", n)
         for n in ("serve", "SERVE_ENGINES", "SecureServer",
                   "MicroBatchQueue", "CodedModel", "open_logits",
                   "repro-serve")]
        + [("serve engine kind", n) for n in api.SERVE_ENGINES]
        + [("SecureServer field", f.name)
           for f in dataclasses.fields(SecureServer)])
    for kind, name in names:
        if f"`{name}`" not in text:
            problems.append(f"docs/API.md: {kind} `{name}` is live but "
                            f"undocumented")
    # the sanctioned sink must also be named in the ARCHITECTURE opening list
    arch = os.path.join(ROOT, "docs", "ARCHITECTURE.md")
    with open(arch) as f:
        if "open_logits" not in f.read():
            problems.append("docs/ARCHITECTURE.md: serving sink "
                            "`open_logits` missing from the sanctioned "
                            "opening list")
    return problems


def check_analysis() -> list:
    """docs/ANALYSIS.md must document every LIVE seclint rule ID."""
    path = os.path.join(ROOT, "docs", "ANALYSIS.md")
    if not os.path.exists(path):
        return ["missing docs/ANALYSIS.md (the seclint rule catalog)"]
    with open(path) as f:
        text = f.read()
    src = os.path.join(ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    try:
        from repro.analysis import RULES
    except Exception as e:  # noqa: BLE001 -- an unimportable analyzer IS a finding
        return [f"repro.analysis failed to import for the docs lint: {e!r}"]
    problems = []
    for rule_id in RULES:
        if f"`{rule_id}`" not in text:
            problems.append(f"docs/ANALYSIS.md: rule `{rule_id}` is in the "
                            "live registry but missing from the catalog")
    return problems


def check_wire_kinds() -> list:
    """docs/ARCHITECTURE.md must name every LIVE wire frame kind: the
    round table there is the human-readable twin of commlint's
    choreography spec, and a kind added to one but not the other is
    exactly the drift COM007 exists to catch in code."""
    path = os.path.join(ROOT, "docs", "ARCHITECTURE.md")
    with open(path) as f:
        text = f.read()
    src = os.path.join(ROOT, "src")
    if src not in sys.path:
        sys.path.insert(0, src)
    try:
        from repro.analysis.choreography import KINDS
    except Exception as e:  # noqa: BLE001 -- an unimportable spec IS a finding
        return [f"choreography spec failed to import for the docs lint: "
                f"{e!r}"]
    problems = []
    for kind in KINDS:
        if f"`{kind}`" not in text:
            problems.append(
                f"docs/ARCHITECTURE.md: wire kind `{kind}` is in the "
                "choreography spec but missing from the round table")
    return problems


def check_fused() -> list:
    """The fused hot loop must stay documented: its entry point
    (ops.fused_step, the one-dispatch Phase-3/4 megakernel) and every
    schedule/tuning knob it introduced.  These are the levers operators
    actually flip, and an undocumented knob is how the bit-exactness
    story rots."""
    arch_p = os.path.join(ROOT, "docs", "ARCHITECTURE.md")
    run_p = os.path.join(ROOT, "docs", "RUNNING.md")
    if not os.path.exists(run_p):
        return ["missing docs/RUNNING.md (the operator guide)"]
    with open(arch_p) as f:
        arch = f.read()
    with open(run_p) as f:
        running = f.read()
    problems = []
    if "ops.fused_step" not in arch + running:
        problems.append("docs: fused hot-loop entry point `ops.fused_step` "
                        "(kernels/fused_step.py) is undocumented")
    for knob in ("REPRO_FUSED_STEP", "REPRO_PALLAS_BLOCKS",
                 "REPRO_SHARDED_OVERLAP"):
        if knob not in running:
            problems.append(f"docs/RUNNING.md: env knob `{knob}` is live "
                            "but undocumented")
    if "repro.kernels.tune" not in running:
        problems.append("docs/RUNNING.md: the block autotuner CLI "
                        "(`python -m repro.kernels.tune`) is undocumented")
    return problems


def main() -> int:
    doc_text = ""
    for rel in ("README.md", os.path.join("docs", "ARCHITECTURE.md")):
        path = os.path.join(ROOT, rel)
        if not os.path.exists(path):
            print(f"missing required doc: {rel}")
            return 1
        with open(path) as f:
            doc_text += f.read()
    problems = (check_packages(doc_text) + check_links() + check_commands()
                + check_api() + check_serve() + check_analysis()
                + check_wire_kinds() + check_fused())
    for p in problems:
        print(p)
    if not problems:
        print(f"docs lint clean: {len(DOC_FILES)} files, all src/repro "
              f"packages documented, all relative links resolve")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
