#!/usr/bin/env python
"""Repo entry point for the seclint static analyzer.

Equivalent to `python -m repro.analysis`; exists so the gate is
runnable from the repo root without remembering the module path:

    PYTHONPATH=src python scripts/seclint.py src/repro
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.__main__ import main  # noqa: E402

if __name__ == "__main__":
    sys.exit(main())
