"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Full production path: model zoo config, AdamW, microbatching, deterministic
data pipeline, async checkpointing, crash-resume.  Default arguments are
sized for this CPU container (a scaled smollm); pass --hundred-m for the
actual ~100M configuration (slower on CPU).

    python examples/train_lm.py --steps 200   # after `pip install -e .`
    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse

from repro.configs import registry
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--hundred-m", action="store_true",
                    help="~100M-param config (CPU: ~a few s/step)")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt_lm")
    args = ap.parse_args()

    if args.hundred_m:
        cfg = registry.get_config("smollm-360m").scaled(
            n_layers=12, d_model=768, n_heads=12, n_kv=4, d_ff=2048,
            vocab=32768)    # ~104M params
        batch, seq = 4, 256
    else:
        cfg = registry.smoke_config("smollm-360m").scaled(
            n_layers=4, d_model=128, n_heads=4, n_kv=2, d_ff=384)
        batch, seq = 8, 128
    print(f"training {cfg.name} variant: ~{cfg.param_count()/1e6:.0f}M params")

    tcfg = trainer.TrainConfig(
        steps=args.steps, global_batch=batch, seq_len=seq,
        microbatch=batch // 2, ckpt_dir=args.ckpt, ckpt_every=50,
        log_every=10)
    params, history = trainer.train(cfg, tcfg)
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
          f"over {args.steps} steps (checkpoints in {args.ckpt})")


if __name__ == "__main__":
    main()
