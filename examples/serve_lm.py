"""Batched serving example: prefill + autoregressive decode with KV cache.

Serves batched requests through the same decode_step the multi-pod dry-run
lowers (decode_32k / long_500k shapes).

    python examples/serve_lm.py --arch zamba2-2.7b   # after `pip install -e .`
    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""

import argparse

import jax

from repro.configs import registry
from repro.models import model_zoo as MZ
from repro.serve import serving


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=[a for a in registry.ARCH_IDS
                             if a != "copml-logreg"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = registry.smoke_config(args.arch)
    bm = MZ.build(cfg)
    params = bm.init_params(jax.random.PRNGKey(0))
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab)
    frontier = None
    fs = MZ._frontier_shape(cfg, args.batch)
    if fs is not None:
        frontier = jax.numpy.full(fs, 0.01, cfg.jdtype)
    out, stats = serving.generate(
        cfg, params, prompts,
        serving.ServeConfig(max_new_tokens=args.new_tokens,
                            cache_len=args.prompt_len + args.new_tokens + 8),
        frontier=frontier)
    print(f"{args.arch}: generated {out.shape} "
          f"prefill {stats['prefill_s']*1e3:.1f}ms  "
          f"decode {stats['tokens_per_s']:.1f} tok/s")
    print("sample:", out[0, -args.new_tokens:].tolist())


if __name__ == "__main__":
    main()
