"""Quickstart: privacy-preserving collaborative logistic regression (COPML).

13 virtual clients jointly train a logistic regression model without any of
them ever seeing another client's data, the intermediate models, or the
gradients -- only the final model is revealed (paper Algorithm 1).

Everything goes through the repro.api front door: a run is a
(workload, protocol, engine) triple and returns a TrainResult.

    pip install -e .          # once, from the repo root
    python examples/quickstart.py

(or skip the install and run with  PYTHONPATH=src python examples/quickstart.py)
"""

try:
    from repro import api
except ModuleNotFoundError:
    raise SystemExit(
        "repro is not importable -- run `pip install -e .` once from the "
        "repo root, or prefix the command with PYTHONPATH=src")


def main():
    wl = api.get_workload("quickstart")
    cfg = wl.cfg
    print(f"COPML: N={wl.n_clients} clients, K={cfg.k} (parallelization), "
          f"T={cfg.t} (privacy), recovery threshold R={cfg.recovery_threshold}")
    print(f"  -> tolerates {wl.n_clients - cfg.recovery_threshold} stragglers "
          f"per iteration, privacy against any {cfg.t} colluding clients")

    secure = api.fit(wl, "copml", "jit", key=0)
    for t in range(0, secure.iters, 10):
        print(f"  iter {t:3d}  accuracy {secure.accuracy[t]:.3f}")

    plain = api.fit(wl, "float", "eager", key=0)
    print(f"\nfinal accuracy: COPML {secure.final_accuracy:.3f} vs float "
          f"logreg {plain.final_accuracy:.3f}"
          f"  (paper Fig. 4: parity within ~1.3 points)")
    print(f"modeled per-client cost on the paper's 40 Mbps WAN: "
          f"COPML {secure.cost['total_s']:.0f}s total "
          f"({secure.cost['comm_s']:.0f}s communication)")


if __name__ == "__main__":
    main()
