"""Quickstart: privacy-preserving collaborative logistic regression (COPML).

13 virtual clients jointly train a logistic regression model without any of
them ever seeing another client's data, the intermediate models, or the
gradients -- only the final model is revealed (paper Algorithm 1).

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.core.baselines import float_logreg, sigmoid
from repro.core.protocol import Copml, CopmlConfig, case1_params
from repro.data import pipeline


def main():
    m, d, n_clients, iters = 260, 16, 13, 30
    x, y = pipeline.classification_dataset(m=m, d=d, seed=0, margin=2.0)

    k, t = case1_params(n_clients)           # paper Case 1: max parallelism
    cfg = CopmlConfig(n_clients=n_clients, k=k, t=t, eta=1.0)
    print(f"COPML: N={n_clients} clients, K={k} (parallelization), "
          f"T={t} (privacy), recovery threshold R={cfg.recovery_threshold}")
    print(f"  -> tolerates {n_clients - cfg.recovery_threshold} stragglers "
          f"per iteration, privacy against any {t} colluding clients")

    proto = Copml(cfg, m, d)
    client_x, client_y = pipeline.split_clients(x, y, n_clients)

    def report(t_, w):
        if t_ % 10 == 0:
            acc = ((sigmoid(x @ np.asarray(w, np.float64)) > .5) == y).mean()
            print(f"  iter {t_:3d}  accuracy {acc:.3f}")

    _, w_secure = proto.train(jax.random.PRNGKey(0), client_x, client_y,
                              iters=iters, callback=report)

    w_float = float_logreg(x, y, eta=1.0, iters=iters)
    acc_s = ((sigmoid(x @ np.asarray(w_secure, np.float64)) > .5) == y).mean()
    acc_f = ((sigmoid(x @ w_float) > .5) == y).mean()
    print(f"\nfinal accuracy: COPML {acc_s:.3f} vs float logreg {acc_f:.3f}"
          f"  (paper Fig. 4: parity within ~1.3 points)")


if __name__ == "__main__":
    main()
