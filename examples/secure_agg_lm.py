"""Beyond-paper: LM training with COPML-coded secure gradient aggregation.

Eight virtual data-owners fine-tune a shared LM; each host's gradient is
quantized (App. A), Shamir-shared, summed in the share domain, and decoded
with the paper's secure truncation -- no host ever sees another's gradient
(information-theoretic, T=2 colluders), and any 3 of 8 hosts suffice to
reconstruct (straggler tolerance).  See core/secure_agg.py + DESIGN.md
section 4.

    python examples/secure_agg_lm.py          # after `pip install -e .`
    PYTHONPATH=src python examples/secure_agg_lm.py
"""

from repro.configs import registry
from repro.core.secure_agg import SecureAggConfig
from repro.train import trainer


def main():
    cfg = registry.smoke_config("smollm-360m")
    sa = SecureAggConfig(n_clients=8, t=2, lq=14, clip=4.0)
    print(f"secure aggregation: N={sa.n_clients} hosts, privacy T={sa.t}, "
          f"straggler budget {sa.n_clients - (sa.t + 1)}")
    tcfg = trainer.TrainConfig(steps=20, global_batch=8, seq_len=64,
                               log_every=2, secure_agg=sa)
    _, hist = trainer.train_secure(cfg, tcfg)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f} "
          f"(every gradient exchange information-theoretically private)")


if __name__ == "__main__":
    main()
