"""Multi-class quickstart: 10-class one-vs-rest training on CODED data.

13 virtual clients jointly train a 10-class classifier without any of them
ever seeing another client's data, the intermediate models, or the
gradients.  The model is a single (d, 10) field matrix: the dataset is
quantized, secret-shared, and LCC-encoded ONCE, and every gradient round
computes all 10 one-vs-rest columns as one class-batched field GEMM
X~^T ghat(X~ W) -- C-fold fewer encode/share collectives than 10
independent binary runs (see `python -m benchmarks.run --stage multiclass`
for the measured/modeled amortization).

    pip install -e .          # once, from the repo root
    python examples/multiclass_quickstart.py

(or skip the install and run with
 PYTHONPATH=src python examples/multiclass_quickstart.py)
"""

try:
    from repro import api
except ModuleNotFoundError:
    raise SystemExit(
        "repro is not importable -- run `pip install -e .` once from the "
        "repo root, or prefix the command with PYTHONPATH=src")


def main():
    wl = api.get_workload("mnist10_like")
    n_classes = wl.objective.n_outputs
    print(f"COPML multi-class: N={wl.n_clients} clients, C={n_classes} "
          f"one-vs-rest classes on ONE dataset encoding "
          f"(K={wl.cfg.k}, T={wl.cfg.t}, R={wl.cfg.recovery_threshold})")
    print(f"  model: ({wl.d}, {n_classes}) field matrix; "
          f"prediction: argmax over the C column scores\n")

    secure = api.fit(wl, "copml", "jit", key=0)
    print(f"secure 10-class training: {secure.iters} iters in "
          f"{secure.wall_time_s:.1f}s, argmax accuracy "
          f"{secure.final_accuracy:.3f} on {wl.test_m} held-out rows")
    print("per-class accuracy:")
    for c, acc in enumerate(secure.per_class_accuracy):
        print(f"  class {c}: {acc:.3f}")

    plain = api.fit(wl, "float", "jit", key=0)
    print(f"\nplaintext one-vs-rest reference: {plain.final_accuracy:.3f} "
          f"(parity gap {plain.final_accuracy - secure.final_accuracy:+.3f})")
    print(f"modeled per-client cost on the paper's 40 Mbps WAN: "
          f"{secure.cost['total_s']:.0f}s total "
          f"({secure.cost['comm_s']:.0f}s communication), amortized over "
          f"all {n_classes} classes")


if __name__ == "__main__":
    main()
