"""The paper's Section V comparison as a registry sweep.

Runs the same workload through every registered protocol (COPML, the
[BH08]-style MPC baseline, plaintext float, polynomial-sigmoid float, and
secure aggregation) on the scan engine, and prints one TrainResult row
each -- the Table-I/Fig-4 comparison reduced to formatting.

    python examples/protocol_matrix.py            # after `pip install -e .`
    PYTHONPATH=src python examples/protocol_matrix.py
"""

try:
    from repro import api
except ModuleNotFoundError:
    raise SystemExit(
        "repro is not importable -- run `pip install -e .` once from the "
        "repo root, or prefix the command with PYTHONPATH=src")


def main():
    wl, iters = "smoke", 10
    print(f"workload {wl!r}, {iters} GD iterations, engine jit\n")
    print(f"{'protocol':14s} {'accuracy':>8s} {'wall_s':>8s} "
          f"{'modeled comm_s':>14s}")
    for name in api.protocol_names():
        res = api.fit(wl, name, "jit", key=0, iters=iters)
        comm = "-" if res.cost is None else f"{res.cost['comm_s']:.1f}"
        print(f"{name:14s} {res.final_accuracy:8.3f} "
              f"{res.wall_time_s:8.2f} {comm:>14s}")
    print("\n(modeled comm prices the paper's 40 Mbps WAN; float protocols "
          "exchange nothing)")


if __name__ == "__main__":
    main()
