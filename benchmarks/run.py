"""Benchmark harness: one registered stage per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--stage fig3,fig4,...]
    PYTHONPATH=src python -m benchmarks.run --list
    PYTHONPATH=src python -m benchmarks.run --stage engine --json
    PYTHONPATH=src python -m benchmarks.run --stage engine --json out.json

Stages come from the STAGES registry (no hand-wired if/elif); each
measurement row records the (workload, protocol, engine) run triple from
the repro.api axes -- stages give a default triple, individual rows may
override.  Output is ``name,us_per_call,derived`` CSV on stdout plus,
with --json, machine-readable trajectory files: one ``BENCH_<stage>.json``
per executed stage (stage, default triple, rows with wall us_per_call and
the derived strings carrying modeled comm/comp where the stage models
them) written into the given directory (default ``.``) -- the per-PR
artifact future sessions diff for perf regressions.  Passing a path
ending in ``.json`` instead writes the legacy combined dump.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import traceback
from typing import Callable


@dataclasses.dataclass(frozen=True)
class Stage:
    """One registered benchmark stage.

    run(report, ctx): `report(name, us, derived, *, workload=, protocol=,
    engine=)` records a row (triple kwargs default to the stage's);
    `ctx` is a shared dict for cross-stage products (the kernel stage
    publishes the measured field MAC/s for the modeled stages)."""
    key: str
    run: Callable
    triple: tuple            # default (workload, protocol, engine) for rows
    doc: str


def build_stages() -> dict:
    """The stage registry, in execution order (kernel feeds fig3/table1)."""
    from . import (analysis_bench, distributed_bench, fig3_speedup,
                   fig4_accuracy, kernel_micro, multiclass_bench,
                   procnet_bench, resilience_bench, roofline_report,
                   serving_bench, table1_breakdown, table2_complexity)

    def kernel(report, ctx):
        ctx["field_macs_per_s"] = kernel_micro.run(report)

    stages = [
        Stage("kernel_micro", kernel, ("synthetic", "-", "jit"),
              "field/kernel microbenchmarks (incl. fused step vs "
              "phase-siloed); calibrates field MAC/s"),
        Stage("engine", lambda report, ctx: kernel_micro.run_engine(report),
              ("engine_micro", "copml", "-"),
              "api.fit engine comparison: eager vs jit scan"),
        Stage("distributed",
              lambda report, ctx: distributed_bench.run(report),
              ("copml_dist_cli", "copml", "sharded:8"),
              "mesh-sharded vs single-device wall time (subprocess)"),
        Stage("resilience",
              lambda report, ctx: resilience_bench.run(report),
              ("smoke_straggler", "copml", "jit"),
              "wall time under FaultPlan churn vs fault-free baseline"),
        Stage("procnet",
              lambda report, ctx: procnet_bench.run(report),
              ("smoke", "copml", "proc:4"),
              "multi-process socket runtime: measured wire bytes + wall"),
        Stage("analysis",
              lambda report, ctx: analysis_bench.run(report),
              ("src/repro", "-", "static"),
              "seclint+commlint static-analysis gate wall time"),
        Stage("multiclass",
              lambda report, ctx: multiclass_bench.run(report),
              ("mnist10_like", "copml", "jit"),
              "encode-once C-class training vs C sequential binary fits"),
        Stage("serving",
              lambda report, ctx: serving_bench.run(report),
              ("smoke", "copml", "jit"),
              "secure serving: queries/sec vs micro-batch size per engine"),
        Stage("fig4", lambda report, ctx: fig4_accuracy.run(report),
              ("fig4", "copml", "jit"),
              "accuracy parity vs plaintext (paper Fig. 4)"),
        Stage("fig3",
              lambda report, ctx: fig3_speedup.run(
                  report, ctx.get("field_macs_per_s")),
              ("paper_scale", "copml", "modeled"),
              "training-time speedup vs MPC baselines (paper Fig. 3)"),
        Stage("table1",
              lambda report, ctx: table1_breakdown.run(
                  report, ctx.get("field_macs_per_s")),
              ("cifar10_paper", "copml", "modeled"),
              "comm/comp/enc breakdown at N=50 (paper Table I)"),
        Stage("table2", lambda report, ctx: table2_complexity.run(report),
              ("table2", "copml", "jit"),
              "measured cost scaling vs complexity claims (paper Table II)"),
        Stage("roofline", lambda report, ctx: roofline_report.run(report),
              ("-", "-", "-"),
              "compiled-program roofline report"),
    ]
    return {s.key: s for s in stages}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stage", "--only", dest="stage", default=None,
                    help="comma-separated subset of registered stages "
                         "(--only kept as an alias)")
    ap.add_argument("--json", nargs="?", const=".", default=None,
                    metavar="DIR_OR_PATH",
                    help="write machine-readable results: one "
                         "BENCH_<stage>.json per executed stage into the "
                         "given directory (default '.'); a path ending in "
                         ".json writes the legacy combined dump instead")
    ap.add_argument("--list", action="store_true",
                    help="print the stage registry and exit")
    args = ap.parse_args(argv)

    stages = build_stages()
    if args.list:
        for s in stages.values():
            print(f"{s.key:12s} {s.doc}")
        return
    selected = None
    if args.stage:
        selected = set(args.stage.split(","))
        unknown = selected - set(stages)
        if unknown:
            ap.error(f"unknown stage(s) {sorted(unknown)}; "
                     f"registered: {sorted(stages)}")

    rows: list = []
    failures: list = []
    ctx: dict = {}
    print("name,us_per_call,derived")

    def make_report(stage: Stage):
        def report(name: str, us_per_call: float, derived: str = "", *,
                   workload=None, protocol=None, engine=None):
            w, p, e = stage.triple
            rows.append({
                "stage": stage.key, "name": name,
                "us_per_call": float(us_per_call), "derived": derived,
                "workload": workload or w, "protocol": protocol or p,
                "engine": engine or e,
            })
            print(f"{name},{us_per_call:.1f},{derived}", flush=True)
        return report

    for stage in stages.values():
        if selected and stage.key not in selected:
            continue
        try:
            stage.run(make_report(stage), ctx)
        except Exception as e:  # noqa: BLE001
            failures.append((stage.key, repr(e)))
            traceback.print_exc()

    if args.json:
        write_json(args.json, rows, failures, stages)

    if failures:
        print(f"{len(failures)} benchmark stages failed", file=sys.stderr)
        sys.exit(1)


def write_json(target: str, rows: list, failures: list,
               stages: dict) -> list:
    """Persist benchmark rows as JSON; returns the file paths written.

    target ending in '.json': one legacy combined dump.  Otherwise target
    is a directory receiving one BENCH_<stage>.json trajectory file per
    stage that produced rows (or failed) -- stable names so successive PRs
    can diff the same stage's numbers."""
    if target.endswith(".json"):
        with open(target, "w") as f:
            json.dump({"rows": rows,
                       "failures": [list(f_) for f_ in failures]}, f,
                      indent=1)
        return [target]
    os.makedirs(target, exist_ok=True)
    paths = []
    failed = {k: msg for k, msg in failures}
    for key in sorted({r["stage"] for r in rows} | set(failed)):
        path = os.path.join(target, f"BENCH_{key}.json")
        with open(path, "w") as f:
            json.dump({
                "stage": key,
                "triple": list(stages[key].triple),
                "rows": [r for r in rows if r["stage"] == key],
                "failure": failed.get(key),
            }, f, indent=1)
        paths.append(path)
    return paths


if __name__ == "__main__":
    main()
