"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,...]

Prints ``name,us_per_call,derived`` CSV rows (one per measurement).
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: kernel,engine,distributed,"
                         "fig3,fig4,table1,table2,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def report(name: str, us_per_call: float, derived: str = ""):
        row = f"{name},{us_per_call:.1f},{derived}"
        rows.append(row)
        print(row, flush=True)

    print("name,us_per_call,derived")
    failures = []

    def stage(key, fn):
        if only and key not in only:
            return None
        try:
            return fn()
        except Exception as e:  # noqa: BLE001
            failures.append((key, e))
            traceback.print_exc()
            return None

    from . import (distributed_bench, fig3_speedup, fig4_accuracy,
                   kernel_micro, roofline_report, table1_breakdown,
                   table2_complexity)

    macs = stage("kernel", lambda: kernel_micro.run(report))
    stage("engine", lambda: kernel_micro.run_engine(report))
    stage("distributed", lambda: distributed_bench.run(report))
    stage("fig4", lambda: fig4_accuracy.run(report))
    stage("fig3", lambda: fig3_speedup.run(report, macs))
    stage("table1", lambda: table1_breakdown.run(report, macs))
    stage("table2", lambda: table2_complexity.run(report))
    stage("roofline", lambda: roofline_report.run(report))

    if failures:
        print(f"{len(failures)} benchmark stages failed", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
