"""Fig. 3 reproduction: total training time vs N, COPML vs MPC baselines.

Two layers of evidence:
  1. MEASURED: wall-clock per-iteration time of the real protocol
     implementations at a reduced scale.  This stage times the
     single-process engines (all N clients on one device, so time ~ N *
     per-client compute with no wire traffic); the `distributed` stage
     (benchmarks/distributed_bench.py) times the mesh-sharded engine whose
     exchanges ARE real collectives (all_to_all / reduce-scatter /
     all_gather) over virtual devices -- see docs/ARCHITECTURE.md,
     "Modeled vs measured communication", for why neither is a WAN number.
  2. MODELED: the validated Table-II cost model, priced with the paper's
     EC2/WAN parameters (40 Mbps) and this host's measured field MAC/s, at
     the paper's full scale (CIFAR-10 m=9019 d=3073, GISETTE m=6000 d=5000,
     J=50) -- reproducing the headline 8.6x / 16.4x speedups.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.baselines import MpcBaseline
from repro.core.cost_model import WanParams, Workload, copml_costs, \
    mpc_baseline_costs
from repro.core.protocol import Copml, CopmlConfig, case1_params, \
    case2_params
from repro.data import pipeline


def run(report, field_macs_per_s: float | None = None):
    hw = WanParams() if field_macs_per_s is None else \
        WanParams(field_macs_per_s=field_macs_per_s)

    # ---- modeled, paper scale (Fig. 3 curves) ----
    for ds, m, d, paper_x in (("cifar10", 9019, 3073, 8.6),
                              ("gisette", 6000, 5000, 16.4)):
        for n in (10, 26, 50):
            k1, _ = case1_params(n)
            k2, t2 = case2_params(n)
            w1 = Workload(m=m, d=d, n=n, k=k1, t=1, iters=50)
            w2 = Workload(m=m, d=d, n=n, k=k2, t=t2, iters=50)
            base = mpc_baseline_costs(w2, hw, scheme="bh08")["total_s"]
            c1 = copml_costs(w1, hw)["total_s"]
            c2 = copml_costs(w2, hw)["total_s"]
            report(f"fig3/{ds}_N{n}_case1_speedup", c1 * 1e6,
                   f"{base / c1:.1f}x_vs_bh08")
            report(f"fig3/{ds}_N{n}_case2_speedup", c2 * 1e6,
                   f"{base / c2:.1f}x_vs_bh08")
        if True:
            report(f"fig3/{ds}_paper_headline", 0.0, f"paper_{paper_x}x")

    # ---- measured, reduced scale ----
    x, y = pipeline.classification_dataset(m=450, d=64, seed=0)
    n = 15
    k, t = case2_params(n)
    cfg = CopmlConfig(n_clients=n, k=k, t=t, eta=1.0)
    proto = Copml(cfg, x.shape[0], x.shape[1])
    cx, cy = pipeline.split_clients(x, y, n)
    key = jax.random.PRNGKey(0)
    state = proto.setup(key, cx, cy)
    step = jax.jit(proto.iteration)
    state = step(key, state)                       # compile
    t0 = time.perf_counter()
    for i in range(3):
        state = step(jax.random.fold_in(key, i), state)
    jax.block_until_ready(state.w_shares)
    copml_dt = (time.perf_counter() - t0) / 3

    mb = MpcBaseline(cfg, x.shape[0], x.shape[1])
    mstate = mb.setup(key, x, y)
    mstep = jax.jit(mb.iteration)
    mstate = mstep(key, mstate)
    t0 = time.perf_counter()
    for i in range(3):
        mstate = mstep(jax.random.fold_in(key, i), mstate)
    jax.block_until_ready(mstate.w_shares)
    mpc_dt = (time.perf_counter() - t0) / 3
    report("fig3/measured_iter_copml", copml_dt * 1e6,
           f"{mpc_dt / copml_dt:.1f}x_vs_bh08_compute_only",
           workload="fig3_measured", engine="eager")
    report("fig3/measured_iter_bh08", mpc_dt * 1e6, "",
           workload="fig3_measured", protocol="mpc_baseline", engine="eager")
