"""Analysis benchmark: the static analyzers ARE a CI gate, so their wall
time is a product metric -- a slow linter erodes the fast lane's budget.

Times three configurations over the real src/repro tree:

* both pass families cold (what the PR fast-lane gate runs);
* the comm pass alone (the choreography checker's marginal cost);
* the sec pass warm through a FindingsCache (what `--changed-only
  --cache` runs approach as the cache fills).

All three must stay clean -- a finding here means the gate is red, which
is a correctness failure, not a perf number -- so `run` asserts on it.
Derived strings carry the file/finding counts and the cache hit rate.
"""

from __future__ import annotations

import os
import tempfile
import time

_SRC_REPRO = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "src", "repro")


def _timed(fn, reps: int = 3):
    """Best-of-`reps` wall time: sub-second analyzer runs jitter well
    past the bench gate's threshold on a loaded host; min() is the
    standard de-noiser for CPU-bound microbenchmarks."""
    best = float("inf")
    out = None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def run(report) -> None:
    from repro.analysis import analyze_paths
    from repro.analysis.cache import FindingsCache

    res, us = _timed(lambda: analyze_paths([_SRC_REPRO], package="repro"),
                     reps=5)
    assert res.active == [], [str(f) for f in res.active]
    report("analysis/both_passes_cold", us,
           f"{len(res.files)}files_0findings")

    # the marginal configurations jitter past the bench gate's threshold
    # on a loaded host (they re-parse the whole tree in ~250ms); keep
    # their numbers visible in `derived` but out of the wall gate, like
    # procnet/setup_wall
    res, us = _timed(
        lambda: analyze_paths([_SRC_REPRO], package="repro",
                              passes=("comm",)))
    assert res.active == []
    report("analysis/comm_pass_cold", 0.0,
           f"{us / 1e3:.0f}ms_{len(res.files)}files")

    with tempfile.TemporaryDirectory() as tmp:
        cache = FindingsCache(os.path.join(tmp, "cache.json"))
        analyze_paths([_SRC_REPRO], package="repro", passes=("sec",),
                      cache=cache)
        cache.save()
        warm = FindingsCache(os.path.join(tmp, "cache.json"))
        res, us = _timed(
            lambda: analyze_paths([_SRC_REPRO], package="repro",
                                  passes=("sec",), cache=warm))
        assert res.active == []
        total = warm.hits + warm.misses
        report("analysis/sec_pass_warm_cache", 0.0,
               f"{us / 1e3:.0f}ms_{warm.hits}of{total}cache_hits")
