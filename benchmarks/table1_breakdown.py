"""Table I reproduction: breakdown of running time at N=50 (CIFAR-10 scale).

Comp / Comm / Enc-Dec columns for [BGW88], [BH08], COPML Case 1 & Case 2,
priced by the Table-II cost model with the paper's WAN parameters and this
host's measured field throughput.  Paper reference totals: 22384 / 7915 /
440 / 916 seconds.
"""

from __future__ import annotations

from repro.core.cost_model import WanParams, Workload, copml_costs, \
    mpc_baseline_costs
from repro.core.protocol import case1_params, case2_params

PAPER = {"bgw": (918, 21142, 324, 22384), "bh08": (914, 6812, 189, 7915),
         "copml_case1": (141, 284, 15, 440), "copml_case2": (240, 654, 22, 916)}


def run(report, field_macs_per_s: float | None = None):
    hw = WanParams() if field_macs_per_s is None else \
        WanParams(field_macs_per_s=field_macs_per_s)
    n, m, d, j = 50, 9019, 3073, 50
    k1, _ = case1_params(n)
    k2, t2 = case2_params(n)

    rows = {
        "bgw": mpc_baseline_costs(
            Workload(m, d, n, k2, t2, j), hw, scheme="bgw"),
        "bh08": mpc_baseline_costs(
            Workload(m, d, n, k2, t2, j), hw, scheme="bh08"),
        "copml_case1": copml_costs(Workload(m, d, n, k1, 1, j), hw),
        "copml_case2": copml_costs(Workload(m, d, n, k2, t2, j), hw),
    }
    for name, c in rows.items():
        p = PAPER[name]
        proto = "copml" if name.startswith("copml") else "mpc_baseline"
        report(f"table1/{name}_comp_s", c["comp_s"] * 1e6,
               f"paper_{p[0]}s", protocol=proto)
        report(f"table1/{name}_comm_s", c["comm_s"] * 1e6,
               f"paper_{p[1]}s", protocol=proto)
        report(f"table1/{name}_encdec_s", c["enc_s"] * 1e6,
               f"paper_{p[2]}s", protocol=proto)
        report(f"table1/{name}_total_s", c["total_s"] * 1e6,
               f"paper_{p[3]}s", protocol=proto)
