"""Serving stage: queries/sec through the secure serving path.

The serving subsystem's claim is ENCODE ONCE, SERVE MANY: `api.serve`
pays one reshare of the trained model into per-client serving shares,
then every micro-batch window is a single packed field GEMM + logit
reconstruction.  This stage measures the two consequences:

* throughput grows with the micro-batch size -- the per-window dispatch
  overhead (queue drain, quantize, GEMM launch, reconstruct) amortizes
  over more queries, so q/s at batch 128 must beat q/s at batch 1 on
  every engine;
* the jit engine's single compiled dispatch per window beats eager's
  op-by-op path once batches are large enough for the window cost to be
  dominated by dispatch count (acceptance: jit >= eager at batch >= 32);
* the one-time encode cost is reported with its per-query amortization
  as a derived row -- the number that goes to zero as the server lives.

Timings are warm best-of-reps around `SecureServer.serve` on a fixed
query stream (the smoke eval rows, tiled), so compile time and the
encode itself stay out of the throughput rows.
"""

from __future__ import annotations

import time

ITERS = 4
REPS = 3
N_QUERIES = 256
BATCHES = (1, 8, 32, 128)
ENGINES = ("eager", "jit", "sharded:1")
_WL = "smoke"


def run(report) -> None:
    import numpy as np

    from repro import api

    res = api.fit(_WL, "copml", "jit", key=0, iters=ITERS, history=False)
    x, _ = api.get_workload(_WL).eval_set()
    rows = np.asarray(x, np.float32)
    queries = np.tile(rows, (-(-N_QUERIES // len(rows)), 1))[:N_QUERIES]

    qps: dict = {}
    encode_s = None
    for engine in ENGINES:
        for bsz in BATCHES:
            # window_ms is effectively infinite: every window flushes on
            # count, so the batch axis is exactly the dispatch-size axis
            srv = api.serve(_WL, res, engine, batch_size=bsz,
                            window_ms=1e9)
            encode_s = srv.stats["encode_s"]
            srv.serve(queries[:bsz])            # compile + warm this shape
            best = float("inf")
            for _ in range(REPS):
                t0 = time.perf_counter()
                srv.serve(queries)
                best = min(best, time.perf_counter() - t0)
            qps[engine, bsz] = N_QUERIES / best
            report(f"serving/{engine}/batch{bsz}",
                   best / N_QUERIES * 1e6,
                   f"{qps[engine, bsz]:.0f}q/s", engine=engine)

    # ------------------------------------------------- derived rows
    # encode-once amortization: the reshare cost per query after serving
    # the whole stream once (ungated -- us_per_call 0.0 like other ratios)
    report("serving/encode_once_s", encode_s * 1e6,
           f"amortized_{encode_s / N_QUERIES * 1e6:.1f}us/q_over_"
           f"{N_QUERIES}q")
    for engine in ENGINES:
        report(f"serving/{engine}/batch_scaling", 0.0,
               f"{qps[engine, BATCHES[-1]] / qps[engine, BATCHES[0]]:.2f}"
               f"x_batch{BATCHES[-1]}_vs_batch{BATCHES[0]}",
               engine=engine)
    for bsz in (32, 128):
        report(f"serving/jit_vs_eager_batch{bsz}", 0.0,
               f"{qps['jit', bsz] / qps['eager', bsz]:.2f}x")
