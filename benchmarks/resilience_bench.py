"""Resilience benchmark: wall time under churn vs the fault-free baseline.

The paper's claim is that straggler/dropout recovery is FREE: a faulty
round decodes from any R of N contributions with the same decode matvec,
so a churned run should cost the same wall time as the fault-free run
(the per-step subsets ride through the compiled scan as array inputs --
no recompile, no extra dispatch).  This stage measures exactly that
margin on the jit engine, plus the one-time host cost of compiling a
plan's decode constants.

Timings on this host are noisy (shared cores): both runs are compiled
and warmed first, then interleaved best-of-reps.
"""

from __future__ import annotations

import time

REPS = 3
ITERS = 8
_WL = "smoke_straggler"          # N=13, K=3, T=1 -> R=10: 3 clients of slack


def _best_of(fn, reps=REPS):
    best = float("inf")
    for _ in range(reps):
        best = min(best, fn())
    return best


def run(report) -> None:
    from repro import api
    from repro.api.faults import FaultPlan

    wl = api.get_workload(_WL)
    thr = wl.cfg.recovery_threshold
    plan = FaultPlan.random(wl.n_clients, ITERS, seed=0, straggle_p=0.15,
                            n_dropouts=1, min_available=thr)
    plan.validate(thr)

    def fit_base():
        return api.fit(_WL, "copml", "jit", key=0, iters=ITERS,
                       history=False, subset="all").wall_time_s

    def fit_churn():
        return api.fit(_WL, "copml", "jit", key=0, iters=ITERS,
                       history=False, faults=plan).wall_time_s

    # host-side plan compilation cost (decode rows per DISTINCT subset;
    # subset enumeration done outside the timed window)
    proto = api.PROTOCOLS["copml"].driver(wl)
    subs = plan.subsets(thr)
    t0 = time.perf_counter()
    proto.plan_constants(subs)
    plan_us = (time.perf_counter() - t0) * 1e6
    report("resilience/plan_compile", plan_us,
           f"{len(set(subs))}_distinct_subsets")

    fit_base(), fit_churn()                       # compile + warm both
    base = churn = float("inf")
    for _ in range(REPS):                         # interleaved best-of-reps
        base = min(base, fit_base())
        churn = min(churn, fit_churn())
    report("resilience/fault_free", base * 1e6, f"{ITERS}it_baseline")
    report("resilience/churned", churn * 1e6,
           f"{churn / base:.2f}x_vs_fault_free_min_avail_"
           f"{int(plan.available_counts.min())}of{wl.n_clients}")
