"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import glob
import json
import os

COLUMNS = ("arch", "shape", "mesh", "dominant")


def load(results_dir: str = "results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs, mesh: str = "pod") -> str:
    rows = [r for r in recs if r.get("mesh") == mesh
            and r.get("status") == "ok"]
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " MODEL_FLOPS/HLO | roofline_frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        peak = r.get("bytes_per_device", {}).get("peak", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {peak:.2f} |")
    skipped = [r for r in recs if r.get("mesh") == mesh
               and "skipped" in r.get("status", "")]
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                     f"SKIP | - | - | - |")
    return "\n".join(lines)


def run(report, results_dir: str = "results/dryrun"):
    recs = load(results_dir)
    ok = [r for r in recs if r.get("status") == "ok"]
    if not ok:
        report("roofline/cells", 0.0, "no_dryrun_results_yet")
        return
    for r in ok:
        report(f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
               r.get("bound_s", max(r["compute_s"], r["memory_s"],
                                    r["collective_s"])) * 1e6,
               f"{r['dominant']}_frac{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    recs = load()
    for mesh in ("pod", "multipod"):
        print(f"\n### {mesh}\n")
        print(markdown_table(recs, mesh))
