"""Roofline stage: dryrun roofline fractions + fused-step HBM traffic.

Two row families feed BENCH_roofline.json:

* aggregated results/dryrun/*.json cells (roofline_fraction per arch/shape,
  as before -- empty until dryruns have been collected on this host), and
* the analytic HBM-traffic model of one Phase-3/4 training step, fused
  (kernels/fused_step.py, ONE dispatch) vs phase-siloed (each phase's
  contraction on its own dispatch).  Byte counts follow from operand
  shapes alone, so the reduction claim holds regardless of backend --
  interpret-mode CPU today, Mosaic TPU later.  These rows are
  derived-only (us_per_call = 0) so bench_diff gates their PRESENCE, not
  wall-time noise.
"""

from __future__ import annotations

import glob
import json
import os

COLUMNS = ("arch", "shape", "mesh", "dominant")

# mnist10_like training shape: 13 clients, m=390 coded rows, d=24, C=10
FUSED_SHAPE = (13, 390, 24, 10)


def step_traffic_bytes(n: int, m: int, d: int, c: int) -> tuple:
    """(fused_bytes, siloed_bytes) of int32 HBM traffic for one step.

    Shared by both schedules: the kernel operands (coded X, coded w,
    gradient coeffs, three (N,) decode/open vectors, five (N, d, C)
    share planes) plus the two outputs (f and the updated shares).  The
    siloed pipeline additionally round-trips every inter-dispatch
    intermediate: f re-read by the offset add, f_adj written+read by the
    decode fold, `common` written+read, c_sh written once and read by
    both the masked open and the truncate finish, c_open written+read.
    The fused kernel keeps all of those in on-chip scratch.
    """
    w4, ndc, dc = 4, n * d * c, d * c
    shared = w4 * (n * m * d + ndc + 2 + 3 * n + 5 * ndc + 2 * ndc)
    intermediates = w4 * (ndc            # f: extra read by the offset add
                          + 2 * ndc      # f_adj round-trip
                          + 2 * dc       # common round-trip
                          + 3 * ndc      # c_sh: write + open read + fin read
                          + 2 * dc)      # c_open round-trip
    return shared, shared + intermediates


def load(results_dir: str = "results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def markdown_table(recs, mesh: str = "pod") -> str:
    rows = [r for r in recs if r.get("mesh") == mesh
            and r.get("status") == "ok"]
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant |"
        " MODEL_FLOPS/HLO | roofline_frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        peak = r.get("bytes_per_device", {}).get("peak", 0) / 2 ** 30
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_flops_ratio']:.3f} | "
            f"{r['roofline_fraction']:.4f} | {peak:.2f} |")
    skipped = [r for r in recs if r.get("mesh") == mesh
               and "skipped" in r.get("status", "")]
    for r in skipped:
        lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                     f"SKIP | - | - | - |")
    return "\n".join(lines)


def run(report, results_dir: str = "results/dryrun"):
    n, m, d, c = FUSED_SHAPE
    fused_b, siloed_b = step_traffic_bytes(n, m, d, c)
    saved = 1.0 - fused_b / siloed_b
    report("roofline/fused_step_bytes_one_dispatch", 0.0,
           f"{fused_b}B_n{n}_m{m}_d{d}_c{c}", workload="mnist10_like")
    report("roofline/siloed_step_bytes_six_dispatch", 0.0,
           f"{siloed_b}B_fused_saves_{saved:.1%}", workload="mnist10_like")

    recs = load(results_dir)
    ok = [r for r in recs if r.get("status") == "ok"]
    if not ok:
        report("roofline/cells", 0.0, "no_dryrun_results_yet")
        return
    for r in ok:
        report(f"roofline/{r['arch']}_{r['shape']}_{r['mesh']}",
               r.get("bound_s", max(r["compute_s"], r["memory_s"],
                                    r["collective_s"])) * 1e6,
               f"{r['dominant']}_frac{r['roofline_fraction']:.4f}")


if __name__ == "__main__":
    recs = load()
    for mesh in ("pod", "multipod"):
        print(f"\n### {mesh}\n")
        print(markdown_table(recs, mesh))
