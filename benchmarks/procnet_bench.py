"""Procnet benchmark: the multi-process socket runtime, measured.

Every other stage prices communication with the WAN cost model
(core/cost_model); this one runs COPML over real OS processes and real
localhost TCP (the proc engine) and records what was MEASURED on the
wire: bytes and frames per protocol phase, the per-phase critical-path
seconds, and the end-to-end wall time.  The byte counts are deterministic
(same protocol, same shapes -> same frames), so they ride as derived-only
rows; the wall row is the one the +20% gate watches.
"""

from __future__ import annotations

ITERS = 6
_WL = "smoke"
_ENGINE = "proc:4"


def run(report) -> None:
    from repro import api
    from repro.analysis import choreography

    res = api.fit(_WL, "copml", _ENGINE, key=0, iters=ITERS, history=False)
    mc = res.measured_comm
    # the measured frame counts are deterministic and must equal the
    # static choreography budget bit for bit (commlint's COM009 spec);
    # a drift here is a protocol bug, not a perf regression
    static = choreography.frames_by_phase(mc["procs"], ITERS, history=False)
    assert mc["frames_by_phase"] == static, (mc["frames_by_phase"], static)
    report("procnet/frames_vs_static", 0.0,
           f"{sum(static.values())}frames_bit_exact_"
           f"{sum(mc['dropped_frames'].values())}dropped")
    report("procnet/fit_wall", mc["wall_s"] * 1e6,
           f"{mc['procs']}procs_{ITERS}it")
    # spawn + per-worker jax import dominate and are host-noisy: keep the
    # number visible in `derived` but out of the wall gate
    report("procnet/setup_wall", 0.0,
           f"{mc['setup_wall_s']:.2f}s_spawn_import_deal")

    for phase in sorted(mc["bytes_by_phase"]):
        report(f"procnet/bytes_{phase}", 0.0,
               f"{mc['bytes_by_phase'][phase]}B_"
               f"{mc['frames_by_phase'][phase]}frames")
    report("procnet/bytes_total", 0.0, f"{mc['total_bytes']}B")

    # measured vs modeled, side by side: the exchange phase's measured
    # critical path against the cost model's per-client comm seconds
    # (they answer different questions -- localhost wire vs WAN model --
    # the point is that both now exist on one row)
    modeled = res.cost["comm_s"] if res.cost else float("nan")
    exch = mc["seconds_by_phase"].get("exchange", 0.0)
    report("procnet/exchange_crit_path", 0.0,
           f"measured_{exch:.3f}s_vs_modeled_wan_{modeled:.1f}s")
