"""Kernel microbenchmarks + field-throughput calibration.

Measures the pure-jnp limb field matmul (the TPU algorithm executed by XLA
CPU) and the paper's own numpy-uint64 arithmetic; the measured MAC/s feeds
cost_model.WanParams.field_macs_per_s so the Fig-3 reproduction is priced
with a real number from THIS host.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field as F
from repro.kernels import ops, ref


def _time(fn, *args, reps=3):
    fn(*args)                      # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.perf_counter() - t0) / reps


def run(report):
    rng = np.random.default_rng(0)
    m, k, n = 256, 1024, 256
    a = jnp.asarray(rng.integers(0, F.P, size=(m, k)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(k, n)).astype(np.int32))

    jitted = jax.jit(F.matmul)
    dt = _time(jitted, a, b)
    macs = m * k * n
    report("kernel_micro/field_matmul_jnp", dt * 1e6,
           f"{macs / dt / 1e6:.1f}_Mmac_s")

    an, bn = np.asarray(a), np.asarray(b)
    dt = _time(lambda x, y: F.np_matmul(x, y), an, bn)
    report("kernel_micro/field_matmul_uint64", dt * 1e6,
           f"{macs / dt / 1e6:.1f}_Mmac_s")

    x = jnp.asarray(rng.integers(0, F.P, size=(512, 512)).astype(np.int32))
    w = jnp.asarray(rng.integers(0, F.P, size=(512,)).astype(np.int32))
    c = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    dt_fused = _time(lambda: ops.coded_gradient(x, w, c, force_pallas=True))
    dt_ref = _time(lambda: jax.jit(ref.coded_gradient)(x, w, c))
    report("kernel_micro/coded_gradient_pallas_interp", dt_fused * 1e6,
           f"ref_{dt_ref * 1e6:.0f}us")

    z = jnp.asarray(rng.integers(0, F.P, size=(1 << 16,)).astype(np.int32))
    dt = _time(lambda: ops.poly_eval(z, c, force_pallas=True))
    report("kernel_micro/poly_eval_pallas_interp", dt * 1e6,
           f"{z.size / dt / 1e6:.1f}_Melem_s")

    return macs / _time(jitted, a, b)      # field MAC/s for the cost model
