"""Kernel microbenchmarks + field-throughput calibration.

Measures the pure-jnp limb field matmul (the TPU algorithm executed by XLA
CPU) and the paper's own numpy-uint64 arithmetic; the measured MAC/s feeds
cost_model.WanParams.field_macs_per_s so the Fig-3 reproduction is priced
with a real number from THIS host.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import field as F
from repro.kernels import ops, ref


def _time(fn, *args, reps=5):
    """Best-of-reps wall time: min is robust to scheduler noise on a
    shared host, unlike the mean."""
    out = fn(*args)                # compile/warm
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        if hasattr(out, "block_until_ready"):
            out.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def run(report):
    rng = np.random.default_rng(0)
    m, k, n = 256, 1024, 256
    a = jnp.asarray(rng.integers(0, F.P, size=(m, k)).astype(np.int32))
    b = jnp.asarray(rng.integers(0, F.P, size=(k, n)).astype(np.int32))

    jitted = jax.jit(F.matmul)
    dt = _time(jitted, a, b)
    macs = m * k * n
    report("kernel_micro/field_matmul_jnp", dt * 1e6,
           f"{macs / dt / 1e6:.1f}_Mmac_s")

    an, bn = np.asarray(a), np.asarray(b)
    dt = _time(lambda x, y: F.np_matmul(x, y), an, bn)
    report("kernel_micro/field_matmul_uint64", dt * 1e6,
           f"{macs / dt / 1e6:.1f}_Mmac_s")

    x = jnp.asarray(rng.integers(0, F.P, size=(512, 512)).astype(np.int32))
    w = jnp.asarray(rng.integers(0, F.P, size=(512,)).astype(np.int32))
    c = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    dt_fused = _time(lambda: ops.coded_gradient(x, w, c, force_pallas=True))
    dt_ref = _time(lambda: jax.jit(ref.coded_gradient)(x, w, c))
    report("kernel_micro/coded_gradient_pallas_interp", dt_fused * 1e6,
           f"ref_{dt_ref * 1e6:.0f}us")

    z = jnp.asarray(rng.integers(0, F.P, size=(1 << 16,)).astype(np.int32))
    dt = _time(lambda: ops.poly_eval(z, c, force_pallas=True))
    report("kernel_micro/poly_eval_pallas_interp", dt * 1e6,
           f"{z.size / dt / 1e6:.1f}_Melem_s")

    run_multiclient(report)
    run_fused_step(report)

    return macs / _time(jitted, a, b)      # field MAC/s for the cost model


def run_fused_step(report):
    """Fused one-dispatch Phase-3/4 step vs the phase-siloed pipeline at the
    mnist10_like training shape (N=13 clients, m=390, d=24, C=10, deg-1
    gradient polynomial).

    The siloed baseline is how the hot loop ran before the megakernel: each
    phase's field contraction on its own accelerator dispatch (coded
    gradient kernel, decode-fold matmul, masked-open matmul) with jnp glue
    between them, so every intermediate round-trips through HBM.  The fused
    path is ops.fused_step -- the same arithmetic as ONE pallas_call.  Both
    run the interpret-mode Pallas path on CPU hosts; the checked equality
    is bit-exactness of the final share update.
    """
    rng = np.random.default_rng(2)
    n, m, d, c, k1 = 13, 390, 24, 10, 8
    q_eta, inv2k1 = 12345, F.host_inv(1 << k1)
    fld = lambda *s: jnp.asarray(                      # noqa: E731
        rng.integers(0, F.P, size=s).astype(np.int32))
    x, w, coeffs = fld(n, m, d), fld(n, d, c), fld(2)
    dfull, rvec = fld(n), fld(n)
    base, xty, wsh, radd, r0sh = (fld(n, d, c) for _ in range(5))
    adv = jnp.zeros((n,), jnp.int32)

    def fused():
        _, new_w = ops.fused_step(x, w, coeffs, adv, dfull, rvec, base, xty,
                                  wsh, radd, r0sh, q_eta=q_eta,
                                  inv2k1=inv2k1, k1=k1, force_pallas=True)
        return new_w

    adj = jax.jit(lambda f: F.add(f, adv[:, None, None]))
    mid = jax.jit(lambda common: F.add(
        F.mul_scalar(F.sub(F.add(base, common.reshape(d, c)[None]), xty),
                     q_eta), radd))
    fin = jax.jit(lambda c_open, c_sh: F.sub(wsh, F.mul_scalar(
        F.sub(F.sub(c_sh, radd),
              F.sub(jnp.broadcast_to(
                  jnp.bitwise_and(c_open.reshape(d, c), (1 << k1) - 1)[None],
                  c_sh.shape), r0sh)), inv2k1)))

    def siloed():
        f = ops.coded_gradient_matrix(x, w, coeffs, force_pallas=True)
        f_adj = adj(f)                                       # dispatch 2
        common = ops.modmatmul(dfull[None], f_adj.reshape(n, -1),
                               force_pallas=True)            # decode fold
        c_sh = mid(common)                                   # scale + mask
        c_open = ops.modmatmul(rvec[None], c_sh.reshape(n, -1),
                               force_pallas=True)            # masked open
        return fin(c_open, c_sh)                             # truncate

    np.testing.assert_array_equal(np.asarray(fused()), np.asarray(siloed()))
    # interleave the two schedules so background load hits both alike
    tf, ts = float("inf"), float("inf")
    for _ in range(9):
        t0 = time.perf_counter()
        fused().block_until_ready()
        tf = min(tf, time.perf_counter() - t0)
        t0 = time.perf_counter()
        siloed().block_until_ready()
        ts = min(ts, time.perf_counter() - t0)
    report("kernel_micro/fused_step_one_dispatch", tf * 1e6,
           f"n{n}_m{m}_d{d}_c{c}", workload="mnist10_like")
    report("kernel_micro/fused_step_phase_siloed", ts * 1e6,
           f"speedup_{ts / tf:.2f}x_fused", workload="mnist10_like")


def run_multiclient(report):
    """Batched multi-client coded gradient (COPML Phase 3, all N clients)
    vs the per-client-vmap baseline, on the default execution path for this
    host (the jnp limb algorithm -- what Copml.local_gradient runs when
    REPRO_USE_PALLAS is unset).  The batched engine packs the 7-bit limbs
    into the GEMM dimensions instead of issuing 16 n=1 matvecs per client.
    """
    rng = np.random.default_rng(1)
    c = jnp.asarray(rng.integers(0, F.P, size=(2,)).astype(np.int32))
    # shapes sized so each timed call is >= tens of ms: sub-ms shapes are
    # dominated by scheduler noise on a shared host
    for n_clients, mk, d in ((8, 1024, 512), (16, 512, 384), (32, 512, 256)):
        x = jnp.asarray(
            rng.integers(0, F.P, size=(n_clients, mk, d)).astype(np.int32))
        w = jnp.asarray(
            rng.integers(0, F.P, size=(n_clients, d)).astype(np.int32))
        vmapped = jax.jit(lambda xx, ww, cc: ref.coded_gradient_vmap(
            xx, ww, cc))
        batched = jax.jit(lambda xx, ww, cc: ref.coded_gradient_batched(
            xx, ww, cc))
        np.testing.assert_array_equal(np.asarray(vmapped(x, w, c)),
                                      np.asarray(batched(x, w, c)))
        # interleave the two candidates so background load hits both alike
        # (both are compiled+warm from the correctness check above)
        tv, tb = float("inf"), float("inf")
        for _ in range(7):
            t0 = time.perf_counter()
            vmapped(x, w, c).block_until_ready()
            tv = min(tv, time.perf_counter() - t0)
            t0 = time.perf_counter()
            batched(x, w, c).block_until_ready()
            tb = min(tb, time.perf_counter() - t0)
        report(f"kernel_micro/coded_gradient_vmap_n{n_clients}", tv * 1e6,
               f"m{mk}_d{d}")
        report(f"kernel_micro/coded_gradient_batched_n{n_clients}", tb * 1e6,
               f"speedup_{tv / tb:.2f}x_vs_vmap")


def run_engine(report):
    """Protocol engine axis via api.fit: eager per-step dispatch vs the
    lax.scan jit engine on the `engine_micro` workload.

    Measures end-to-end training wall time (setup included for both; both
    step programs are compiled and warm after the first fit, so the delta
    is per-iteration dispatch only).  On a single CPU host the two are
    near wall parity -- the scan engine's wins are the single dispatch (no
    N-step Python round-trips, which matters on real accelerators) and the
    in-graph model history that makes callbacks free."""
    from repro import api

    wl, iters = "engine_micro", 20
    engines = ("eager", "jit")
    best = {e: float("inf") for e in engines}
    for e in engines:                          # compile/warm both
        api.fit(wl, "copml", e, key=0, iters=iters, history=False)
    for _ in range(3):                         # interleaved best-of-reps
        for e in engines:
            res = api.fit(wl, "copml", e, key=0, iters=iters, history=False)
            best[e] = min(best[e], res.wall_time_s)
    for e in engines:
        dt = best[e]
        report(f"kernel_micro/copml_train_{e}_{iters}it", dt * 1e6,
               f"{iters / dt:.1f}_steps_s", engine=e)
