"""Multiclass stage: encode-once C-class training vs C sequential binary fits.

The class-batched objective's claim is an AMORTIZATION: one COPML run over
a (d, C) matrix model quantizes, secret-shares, and LCC-encodes the dataset
ONCE and pays only the C-wide model encode/decode per iteration, while C
independent binary fits repeat the dominant dataset-sharing collectives C
times.  This stage reports both sides of that claim:

* modeled per-client communication (core/cost_model with the class-width
  axis `c`): encode-once vs C x the binary cost -- the acceptance number;
* honest wall time on the jit engine for both strategies.  The sequential
  baseline reuses ONE compiled binary program across all C one-vs-rest
  label vectors (same Copml instance, same scan shape), so the comparison
  is steady-state field work, not compile noise; on this CPU host the
  absolute times are noisy (shared cores) and the matrix GEMM's advantage
  is smaller than the modeled-comm one -- both numbers are reported as
  measured.
"""

from __future__ import annotations

import dataclasses
import time

ITERS = 6
REPS = 2
_WL = "mnist10_like"


def run(report) -> None:
    import jax
    import numpy as np

    from repro import api
    from repro.core import cost_model
    from repro.core.protocol import Copml

    wl = api.get_workload(_WL)
    n_classes = wl.objective.n_outputs

    # ---------------------------------------------------- modeled comm
    cw = cost_model.Workload(m=wl.m, d=wl.d, n=wl.n_clients, k=wl.cfg.k,
                             t=wl.cfg.t, iters=ITERS, r=wl.cfg.r,
                             c=n_classes)
    once = cost_model.copml_costs(cw)
    binary = cost_model.copml_costs(dataclasses.replace(cw, c=1))
    seq_comm = n_classes * binary["comm_s"]
    report("multiclass/modeled_comm_encode_once_s", once["comm_s"] * 1e6,
           f"{once['comm_s']:.1f}s")
    report("multiclass/modeled_comm_sequential_s", seq_comm * 1e6,
           f"{n_classes}x_binary={seq_comm:.1f}s")
    report("multiclass/modeled_comm_ratio", 0.0,
           f"{seq_comm / once['comm_s']:.2f}x_encode_once_advantage")
    report("multiclass/modeled_comp_encode_once_s", once["comp_s"] * 1e6,
           f"{once['comp_s']:.2f}s_vs_seq_{n_classes * binary['comp_s']:.2f}s")

    # ----------------------------------------------------- measured wall
    def fit_multiclass():
        return api.fit(_WL, "copml", "jit", key=0, iters=ITERS,
                       history=False).wall_time_s

    cx, cy = wl.client_data()
    proto = Copml(wl.cfg, wl.m, wl.d)          # ONE binary driver: the scan
    #                                            compiles once for all C fits
    key = jax.random.PRNGKey(0)
    class_labels = [[(np.asarray(c_y) == c).astype("float32")
                     for c_y in cy] for c in range(n_classes)]

    def fit_sequential():
        t0 = time.perf_counter()
        for c in range(n_classes):
            proto.train(key, cx, class_labels[c], ITERS)
        return time.perf_counter() - t0

    fit_multiclass(), fit_sequential()          # compile + warm both
    best_mc = best_seq = float("inf")
    for _ in range(REPS):                       # interleaved best-of-reps
        best_mc = min(best_mc, fit_multiclass())
        best_seq = min(best_seq, fit_sequential())
    report("multiclass/wall_encode_once", best_mc * 1e6,
           f"{n_classes}_classes_{ITERS}_iters")
    report("multiclass/wall_sequential", best_seq * 1e6,
           f"{n_classes}_binary_fits_shared_compile")
    report("multiclass/wall_ratio", 0.0, f"{best_seq / best_mc:.2f}x")

    # honest end-to-end quality number for the same workload
    res = api.fit(_WL, "copml", "jit", key=0, history=False)
    report("multiclass/argmax_accuracy", res.wall_time_s * 1e6,
           f"{res.final_accuracy:.4f}")
