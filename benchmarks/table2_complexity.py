"""Table II validation: measured per-client cost scaling vs the asymptotic
complexity claims.

  computation O(m d^2 / K)  -> measured iteration time should DROP ~1/K
  encoding    O(m d N (K+T) / K) -> encode time roughly flat in K (m-term)
  communication O(m d N / K + d N J)

We time the real protocol at reduced scale for K in {2, 4, 8} with fixed
N, m, d and report the measured ratios next to the predicted ones.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.protocol import Copml, CopmlConfig
from repro.data import pipeline


def run(report):
    x, y = pipeline.classification_dataset(m=768, d=48, seed=0)
    n = 26
    times = {}
    for k in (2, 4, 8):
        cfg = CopmlConfig(n_clients=n, k=k, t=1, eta=1.0)
        proto = Copml(cfg, x.shape[0], x.shape[1])
        cx, cy = pipeline.split_clients(x, y, n)
        key = jax.random.PRNGKey(0)
        state = proto.setup(key, cx, cy)
        # time ONLY the per-client local gradient (the O(md^2/K) term)
        coded_w = proto.encode_model(key, state.w_shares)
        fn = jax.jit(proto.local_gradient)
        fn(state.coded_x, coded_w)
        t0 = time.perf_counter()
        for _ in range(5):
            out = fn(state.coded_x, coded_w)
        jax.block_until_ready(out)
        times[k] = (time.perf_counter() - t0) / 5
        report(f"table2/local_grad_K{k}", times[k] * 1e6,
               f"mk_{-(-x.shape[0] // k)}")
    # computation should scale ~ 1/K (all N clients simulated serially, so
    # total ~ N * (m/K) d -> ratio K=2 vs K=8 ~ 4x)
    ratio = times[2] / times[8]
    report("table2/comp_scaling_K2_over_K8", 0.0,
           f"{ratio:.2f}x_predicted_4x")
