"""Fig. 4 reproduction: COPML accuracy vs conventional logistic regression.

Real CIFAR-10/GISETTE are unavailable offline; we run the REAL protocol on
synthetic binary tasks with the paper's aspect ratios at reduced m (CPU
budget) and report the PARITY GAP, which is the quantity Fig. 4
demonstrates (paper: 80.45% vs 81.75% on CIFAR-10; tie at 97.5% GISETTE).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.baselines import float_logreg, sigmoid
from repro.core.protocol import Copml, CopmlConfig, case2_params
from repro.data import pipeline


def _acc(x, y, w):
    return float(((sigmoid(x @ np.asarray(w, np.float64)) > .5) == y).mean())


def run(report):
    for ds, d, margin in (("cifar10_like", 96, 1.2),
                          ("gisette_like", 128, 3.0)):
        x, y, xt, yt = pipeline.classification_dataset(
            m=480, d=d, seed=5, margin=margin, test_m=160)
        n = 15
        k, t = case2_params(n)
        cfg = CopmlConfig(n_clients=n, k=k, t=t, eta=1.0)
        proto = Copml(cfg, x.shape[0], x.shape[1])
        cx, cy = pipeline.split_clients(x, y, n)
        t0 = time.perf_counter()
        _, w = proto.train(jax.random.PRNGKey(0), cx, cy, iters=40)
        dt = time.perf_counter() - t0
        wf = float_logreg(x, y, 1.0, 40)
        acc_c, acc_f = _acc(xt, yt, np.asarray(w)), _acc(xt, yt, wf)
        report(f"fig4/{ds}_copml_acc", dt * 1e6, f"{acc_c:.4f}")
        report(f"fig4/{ds}_float_acc", 0.0, f"{acc_f:.4f}")
        report(f"fig4/{ds}_parity_gap", 0.0, f"{acc_f - acc_c:+.4f}")
