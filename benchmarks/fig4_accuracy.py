"""Fig. 4 reproduction: COPML accuracy vs conventional logistic regression.

Real CIFAR-10/GISETTE are unavailable offline; we run the REAL protocol on
synthetic binary tasks with the paper's aspect ratios at reduced m (CPU
budget) and report the PARITY GAP, which is the quantity Fig. 4
demonstrates (paper: 80.45% vs 81.75% on CIFAR-10; tie at 97.5% GISETTE).

Both runs go through api.fit -- the comparison is two rows of the
(workload, protocol, engine) grid, scored on the workload's held-out
eval split.
"""

from __future__ import annotations

from repro import api


def run(report):
    for ds in ("cifar10_like", "gisette_like"):
        copml = api.fit(ds, "copml", "jit", key=0, history=False)
        plain = api.fit(ds, "float", "eager", key=0, history=False)
        gap = plain.final_accuracy - copml.final_accuracy
        report(f"fig4/{ds}_copml_acc", copml.wall_time_s * 1e6,
               f"{copml.final_accuracy:.4f}", workload=ds)
        report(f"fig4/{ds}_float_acc", plain.wall_time_s * 1e6,
               f"{plain.final_accuracy:.4f}", workload=ds,
               protocol="float", engine="eager")
        report(f"fig4/{ds}_parity_gap", 0.0, f"{gap:+.4f}", workload=ds,
               protocol="copml_vs_float", engine="-")
