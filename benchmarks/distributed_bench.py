"""Distributed stage: sharded-vs-single-device COPML wall time.

Multiple devices require XLA_FLAGS=--xla_force_host_platform_device_count
to be set BEFORE jax initializes, so the measurement runs in a fresh
subprocess (launch/copml_dist.py --bench) and its CSV rows are relayed to
the harness.  On one CPU host the virtual devices share physical cores:
the numbers record collective/protocol overhead (and any XLA thread-level
parallelism), not real multi-chip scaling -- see docs/ARCHITECTURE.md,
"Modeled vs measured communication".
"""

from __future__ import annotations

import os
import subprocess
import sys

DEVICES = 8


def run(report) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={DEVICES} "
                        + env.get("REPRO_EXTRA_XLA_FLAGS", ""))
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.copml_dist", "--bench",
         "--devices", str(DEVICES), "--clients", "16", "--iters", "5",
         "--m", "832", "--d", "64"],
        capture_output=True, text=True, env=env, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"copml_dist --bench failed:\n{out.stderr[-2000:]}")
    seen = 0
    for line in out.stdout.splitlines():
        if line.startswith("copml_dist/"):
            name, us, derived = line.split(",", 2)
            engine = f"sharded:{DEVICES}" if "sharded" in name else "jit"
            report(name, float(us), derived, engine=engine)
            seen += 1
    assert seen >= 2, f"expected bench rows, got stdout:\n{out.stdout[-800:]}"
