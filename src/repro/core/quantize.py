"""Fixed-point quantization into F_p (paper Appendix A).

phi(x) = x if x >= 0 else p + x  (two's-complement-style field embedding),
applied to Round(2^lx * x).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import field


def quantize(x, lx: int):
    """Real array -> field elements.  Requires |x| * 2^lx < p/2."""
    scaled = jnp.round(x * float(1 << lx))
    q = scaled.astype(jnp.int32)
    return jnp.where(q < 0, q + field.P, q).astype(field.FIELD_DTYPE)


def dequantize(u, lx: int):
    """Field elements -> real array (inverse of phi, then unscale).

    Elements above p/2 are interpreted as negatives.
    """
    signed = jnp.where(u > field.P // 2, u - field.P, u)
    return signed.astype(jnp.float32) / float(1 << lx)


def signed_value(u):
    """Field -> signed integer representative in (-p/2, p/2]."""
    return jnp.where(u > field.P // 2, u - field.P, u)


def quantization_noise_variance(d: int, m: int, k1: int) -> float:
    """sigma^2 bound from Theorem 1: d * 2^{2(k1-1)} / m^2 ...

    expressed in the *unscaled* (real) domain used by the convergence bound,
    i.e. the variance of the secure-truncation rounding noise on the gradient.
    The bound in the paper is stated in fixed-point units; after the eta/m
    scaling it reduces to d / (4 m^2) per unit step in the truncated grid.
    We report the paper's literal expression.
    """
    return d * float(2 ** (2 * (k1 - 1))) / float(m) ** 2
