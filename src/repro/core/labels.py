"""Secrecy-domain labels: the type vocabulary of the seclint analyzer.

COPML's security argument is a discipline the Python type system never
sees: secret values exist only as Shamir shares or LCC-coded slices, may
be combined only through exact mod-p field ops, and may be *opened* only
at the protocol's sanctioned decode points (share reconstruction, the
Phase-4 gradient decode, the final model opening).  These aliases make
that discipline visible in annotations, and `repro.analysis` (seclint)
enforces it statically: parameter/return/field annotations written with
these names are the analyzer's ground truth for taint seeding and for
what a function is allowed to return.

All aliases are plain `jax.Array` at runtime -- zero cost, no wrappers;
they exist for humans and for the AST analyzer.

  Share       Shamir secret-shares of a protocol value (client axis
              leading, by convention).  Individual shares may be
              exchanged between clients, but the underlying secret may
              only be recovered through `shamir.reconstruct*` /
              `mpc.open_shares`.
  Coded       an LCC-coded slice (Lagrange evaluation of data + mask
              blocks).  Hides the data against any T colluding clients;
              still secret -- decodable only through `lagrange.lcc_decode`
              or the Phase-4 decode row inside `Copml.decode_and_update`.
  SecretRand  dealer/offline randomness (sharing-polynomial coefficients,
              LCC mask blocks, TruncPr pads).  Leaking it breaks the
              hiding argument exactly like leaking a secret.
  Public      a field-domain array that is public protocol state
              (Lagrange/power matrices, decode rows, quantized public
              constants).  Field rules still apply (exact mod-p
              arithmetic); secrecy rules do not.
  Opened      the result of a *sanctioned* declassification: a value
              that has passed through a registered decode point and is
              intentionally public (e.g. the final dequantized model).
              Annotating a function `-> Opened` declares it a declassify
              sink -- seclint trusts it, so new `Opened` annotations on
              protocol code deserve review scrutiny.

Scalar secrecy does not decay through arithmetic: anything computed from
a Share/Coded/SecretRand value stays secret until a sanctioned sink.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, Any

if TYPE_CHECKING:  # pragma: no cover -- runtime value is irrelevant
    import jax

    Array = jax.Array
else:
    Array = Any

# secret domains
Share = Array
Coded = Array
SecretRand = Array

# public domains
Public = Array
Opened = Array

#: every label name the analyzer recognizes in annotations
LABEL_NAMES = ("Share", "Coded", "SecretRand", "Public", "Opened")
