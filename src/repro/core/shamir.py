"""Shamir T-out-of-N secret sharing over F_p for arbitrary-shape arrays.

Shares are stacked on a leading axis of length N: shares[i] is client i's
share, i.e. h(lambda_i) where h(z) = secret + z*R_1 + ... + z^T * R_T.

Evaluation points lambda_1..lambda_N are public static ints, so the power /
interpolation matrices are computed exactly on the host and enter the traced
program as constants -- share generation and reconstruction are then a
single field matmul each (mul-by-public-constant + add = *local* MPC ops,
Appendix C Remark 3), fully vectorized so a 512-client protocol traces to a
handful of HLO ops.
"""

from __future__ import annotations

from functools import lru_cache
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import field
from .labels import Opened, Share


def default_eval_points(n: int, offset: int = 1) -> tuple:
    """N distinct public evaluation points (1..N by default)."""
    return tuple(range(offset, offset + n))


@lru_cache(maxsize=None)
def _power_matrix(points: tuple, t: int) -> np.ndarray:
    """P[i, j] = lambda_i^{j+1} mod p, shape (N, T)."""
    out = np.zeros((len(points), t), dtype=np.int64)
    for i, lam in enumerate(points):
        acc = 1
        for j in range(t):
            acc = (acc * (int(lam) % field.P)) % field.P
            out[i, j] = acc
    return out.astype(np.int32)


@lru_cache(maxsize=None)
def _recon_matrix(points: tuple) -> np.ndarray:
    """Lagrange weights at z=0 for the given nodes, shape (1, R)."""
    return field.host_lagrange_coeffs(points, [0])


def share(key, secret, t: int, n: int,
          points: Sequence[int] | None = None) -> Share:
    """Create N Shamir shares of `secret` with threshold t.

    Returns int32 array of shape (N, *secret.shape).  One field matmul:
    shares = secret + P @ R  with P the public (N, T) power matrix.
    """
    if points is None:
        points = default_eval_points(n)
    points = tuple(points)
    assert len(points) == n
    if t == 0:
        return jnp.broadcast_to(secret[None], (n,) + secret.shape)
    coeffs = field.random_field(key, (t,) + secret.shape)  # R_1..R_T
    pmat = jnp.asarray(_power_matrix(points, t))            # (N, T)
    mix = field.matmul(pmat, coeffs.reshape(t, -1))         # (N, numel)
    return field.add(mix.reshape((n,) + secret.shape), secret[None])


def reconstruct(shares: Share, t: int, points: Sequence[int] | None = None,
                subset: Sequence[int] | None = None) -> Opened:
    """Reconstruct the secret from shares (leading axis = clients).

    Any t+1 shares suffice; `subset` selects which client indices to use
    (defaults to the first t+1) -- exercising this is the straggler story.
    """
    n = shares.shape[0]
    if points is None:
        points = default_eval_points(n)
    if subset == "all":
        # interpolate from ALL N shares: same value (degree-T polynomial,
        # N >= T+1 nodes), but on a mesh the contraction stays fully sharded
        # (reduce-scatter) instead of idling N-T-1 devices -- the inverse of
        # the paper's footnote-4 WAN optimization (EXPERIMENTS.md Perf).
        subset = tuple(range(n))
    elif subset is None:
        subset = tuple(range(t + 1))
    else:
        subset = tuple(subset)[: t + 1]
    assert len(subset) >= t + 1
    r = len(subset)
    lams = tuple(points[i] for i in subset)
    w = jnp.asarray(_recon_matrix(lams))                    # (1, r)
    sub = shares[jnp.asarray(subset)] if list(subset) != list(range(r)) \
        else shares[: r]
    out = field.matmul(w, sub.reshape(r, -1))
    return out.reshape(shares.shape[1:])


def step_subset_arrays(step_subsets, r: int, weight_fn) -> tuple:
    """Host-compile per-step subsets into the (iters, r) gather-index and
    weight arrays the dynamic decode paths consume.

    weight_fn(subset_tuple) -> (r,) int32 public decode/reconstruction row;
    called once per DISTINCT subset (host work is O(#distinct), not
    O(iters)).  Shared by Copml.plan_constants (LCC decode rows) and
    secure_agg.selection_arrays (Shamir reconstruction weights)."""
    cache: dict = {}
    idx = np.zeros((len(step_subsets), r), np.int32)
    wts = np.zeros((len(step_subsets), r), np.int32)
    for s, sub in enumerate(step_subsets):
        sub = tuple(int(i) for i in sub)
        assert len(sub) >= r, (
            f"step {s} subset has {len(sub)} < {r} clients")
        sub = sub[:r]
        if sub not in cache:
            cache[sub] = weight_fn(sub)
        idx[s] = sub
        wts[s] = cache[sub]
    return jnp.asarray(idx), jnp.asarray(wts)


def recon_weights(points: Sequence[int], subset: Sequence[int]) -> np.ndarray:
    """Host-side (r,) Lagrange weights at z=0 for `subset` of the share
    points -- the public constant `reconstruct_dyn` pairs with its traced
    gather indices.  Computed exactly with Python ints (lru-cached)."""
    lams = tuple(int(points[i]) for i in subset)
    return _recon_matrix(lams)[0]


def reconstruct_dyn(shares: Share, idx, weights) -> Opened:
    """Reconstruct with TRACED subset indices and precomputed weights.

    idx: (r,) int32 gather indices into the client axis; weights: (r,) the
    matching `recon_weights` row.  Identical field math to `reconstruct`
    with a static subset, but the subset can change per scan step inside a
    single compiled program -- the per-step share selection of the
    fault-injection engines (any r = T+1 holders suffice).
    """
    r = idx.shape[0]
    sub = shares[idx]                                       # (r, ...)
    out = field.matmul(jnp.asarray(weights).reshape(1, r), sub.reshape(r, -1))
    return out.reshape(shares.shape[1:])


def share_batch(key, secrets, t: int, n: int,
                points: Sequence[int] | None = None) -> Share:
    """Share J independent secrets (leading axis = owners) in ONE matmul:
    secrets (J, ...) -> shares (J, N, ...).

    Because every owner uses the same public power matrix, the owner axis
    folds into the element axis -- which is exactly what `share` of the
    stacked array already computes (its coefficient draw is (T, J, ...):
    independent per-owner polynomials), so this is share + transpose."""
    return jnp.swapaxes(share(key, secrets, t, n, points), 0, 1)


def reshare(key, shares: Share, t: int, n: int,
            points: Sequence[int] | None = None) -> Share:
    """Degree reduction by re-sharing (BGW): every client re-shares its share
    with a fresh degree-t polynomial; the new shares of the secret are the
    lambda-weighted combination of the incoming sub-shares.

    `shares` may lie on a polynomial of degree up to n-1 (e.g. 2t after a
    local multiply); output shares lie on a fresh degree-t polynomial.
    """
    if points is None:
        points = default_eval_points(n)
    points = tuple(points)
    sub = share_batch(key, shares, t, n, points)  # (owner, holder, ...)
    w = field.host_lagrange_coeffs(points, [0])[0]  # (N,) weights at 0
    wj = jnp.asarray(w)[:, None]                    # (N, 1)
    flat = sub.reshape(n, -1)                       # (owner, holder*numel)
    out = field.matmul(wj.T, flat)                  # interpolate over owners
    return out.reshape(shares.shape)
