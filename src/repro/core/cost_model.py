"""Communication/computation cost model (paper Table II + Appendix C/D).

Used by benchmarks/fig3_speedup.py to reproduce the paper's Fig. 3 / Table I
on the EC2-like WAN parameters (40 Mbps, m3.xlarge) and by the roofline
analysis to price the COPML collective traffic on TPU ICI.

These are MODELED wire costs.  The implementation's measured counterpart
exists at two levels: the single-process engines exchange nothing (all N
clients share one device), while Copml.train_sharded runs the same element
counts as real mesh collectives (all_to_all for share distribution,
reduce-scatter for encode reconstruction, all_gather for openings) --
benchmarks/run.py --only distributed records its wall time on virtual
devices.  The modeled-vs-measured caveat is spelled out in
docs/ARCHITECTURE.md ("Modeled vs measured communication").

All counts are per-client, per the paper's Section V-C accounting, in field
elements (multiply by ~bytes_per_elem for bytes; the paper's 64-bit impl
ships 8 B/elem, our int32 impl ships 4 B/elem).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WanParams:
    bandwidth_mbps: float = 40.0       # paper Section V-A
    latency_s: float = 0.05            # WAN RTT ~ 100 ms
    # measured on this host by benchmarks/kernel_micro.py; the paper's
    # m3.xlarge achieves a similar order for 64-bit modular matmul
    field_macs_per_s: float = 2.0e8
    bytes_per_elem: int = 8


@dataclasses.dataclass(frozen=True)
class Workload:
    m: int
    d: int
    n: int
    k: int
    t: int
    iters: int
    r: int = 1
    c: int = 1       # model columns (1 = vector model; C for one-vs-rest)


def copml_costs(w: Workload, hw: WanParams = WanParams()) -> dict:
    """Per-client costs of COPML (Table II row).

    comm elements:  (m/K)dN  (dataset coded slices, paid ONCE regardless of
                    the model width C)  +  dCNJ (model encodings)
                    + dCNJ (local computation shares)
    compute MACs:   2(m/K)dC J     (Eq. 7 matmul pair, dominant)
    encoding MACs:  (m/K)dN(K+T)   +  dCN(K+T)J

    The C > 1 terms are what the `multiclass` benchmark stage compares
    against C independent binary runs: encode-once amortizes the dominant
    dataset-sharing term across all C classes.
    """
    m, d, n, k, t, j, c = w.m, w.d, w.n, w.k, w.t, w.iters, w.c
    comm_elems = m * d * n / k + 2 * d * c * n * j
    # X~ w~  +  X~^T g  as matvec chain: 2*(m/K)*d*C MACs per iteration.
    # (The paper prices the Gram form O(m d^2 / K); the matvec chain is
    # strictly cheaper for J < d/2 and is what our implementation does.)
    comp_macs = 2.0 * (m / k) * d * c * j
    enc_macs = (m / k) * d * n * (k + t) + d * c * n * (k + t) * j
    return _price(comm_elems, comp_macs, enc_macs, hw, rounds=3 * j + 2)


def mpc_baseline_costs(w: Workload, hw: WanParams = WanParams(),
                       scheme: str = "bh08", groups: int = 3) -> dict:
    """Per-client costs of the optimized Appendix-D baselines.

    The baselines perform degree reduction PER MULTIPLICATION GATE (the
    paper: "intensive communication and computation to carry out a degree
    reduction step for secure multiplication").  Gates per iteration per
    subgroup: z = Xw has (m/G)*d scalar gates, the degree-r Horner chain
    r*(m/G), X^T ghat another (m/G)*d.  Per client per gate: BH08 masks +
    opens one value (~2 elements on the wire); BGW re-shares to all N_g.
    This accounting reproduces the paper's Table I within ~2x:
    BGW 21142 s, BH08 6812 s comm at N=50/CIFAR-10.
    """
    m, d, n, j = w.m, w.d, w.n, w.iters
    n_g = max(1, n // groups)
    gates_per_iter = (2.0 * (m / groups) * d + w.r * (m / groups)) * w.c
    per_gate = float(n_g) if scheme == "bgw" else 2.0
    comm_elems = (m / n) * d * n_g                 # initial data sharing
    comm_elems += gates_per_iter * per_gate * j
    comp_macs = 2.0 * (m / groups) * d * w.c * j   # local share matmuls
    enc_macs = gates_per_iter * n_g * j            # reduction encode/decode
    return _price(comm_elems, comp_macs, enc_macs, hw,
                  rounds=(2 + w.r) * j + 1)


def _price(comm_elems, comp_macs, enc_macs, hw: WanParams, rounds: int) -> dict:
    comm_s = comm_elems * hw.bytes_per_elem * 8 / (hw.bandwidth_mbps * 1e6)
    comm_s += rounds * hw.latency_s
    comp_s = comp_macs / hw.field_macs_per_s
    enc_s = enc_macs / hw.field_macs_per_s
    return {"comm_s": comm_s, "comp_s": comp_s, "enc_s": enc_s,
            "total_s": comm_s + comp_s + enc_s}


def speedup(w: Workload, hw: WanParams = WanParams(),
            scheme: str = "bh08") -> float:
    base = mpc_baseline_costs(w, hw, scheme)["total_s"]
    ours = copml_costs(w, hw)["total_s"]
    return base / ours


def proc_net_frames(procs: int, iters: int, history: bool = False) -> dict:
    """Exact per-phase SENT frame counts of one clean proc:P run.

    The analytic side of the modeled-vs-measured story for the
    multi-process engine: commlint (COM009) cross-checks these closed
    forms against the frame budget derived from the choreography spec in
    analysis/choreography.py, and the procnet benchmark + engine tests
    compare both against the live measured_comm["frames_by_phase"]
    counters bit-for-bit.  Frames are counted at the SEND side (sends
    never block), so the totals are timing-invariant: stale frames a
    slow worker's recv_any later drops are still counted here and only
    show up separately in measured_comm["dropped_frames"].

    Closed forms (P = procs, J = iters):
      setup      = P(P-1)/2 + 6P   HELLO mesh + coordinator dials, then
                                   LISTEN/SESSION/READY/START/BYE and
                                   the per-worker HELLO to the coord
      encode     = P(P-1) * J      ENC all-to-all
      exchange   = P(P-1) * J      SHARE all-to-all
      trunc_open = 2P * J          OPEN gather + OPENED broadcast
      open_model = P*J [history] + P   per-step opening + RESULT
    Zero-count phases are omitted so the dict compares directly with
    measured_comm["frames_by_phase"] at any P.
    """
    p, j = int(procs), int(iters)
    out = {
        "setup": p * (p - 1) // 2 + 6 * p,
        "encode": p * (p - 1) * j,
        "exchange": p * (p - 1) * j,
        "trunc_open": 2 * p * j,
        "open_model": (p * j if history else 0) + p,
    }
    return {phase: n for phase, n in out.items() if n}
