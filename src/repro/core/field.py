"""Prime-field arithmetic over F_p, p = 2^26 - 5, in pure int32 JAX.

This is the substrate for every MPC/LCC operation in COPML.  The paper's
64-bit implementation relies on "mod once per inner product" with
d * (p-1)^2 <= 2^64 - 1 (Appendix A).  TPUs have no 64-bit vector path, so we
adapt the same lazy-reduction idea to int32:

* field elements live in [0, p) and always fit in 26 bits;
* products are computed by 13-bit limb decomposition -- every intermediate
  stays strictly below 2^31 (proofs inline below);
* matmuls decompose operands into four 7-bit limbs so the partial products
  (< 2^14) can be accumulated EXACTLY in f32 on the MXU for up to 2^10
  contraction elements per chunk, then recombined modularly in int32.

Everything here is jit-able, shard_map-able, and TPU-lowerable as-is.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# The paper's prime for 64-bit CIFAR-10 runs: the largest prime below 2^26
# such that d * (p-1)^2 <= 2^64 - 1 for d = 3072.  2^26 = p + 5, which gives
# the cheap folding rule  t = (t >> 26) * 5 + (t & MASK26)  (mod p).
P_BITS = 26
P = (1 << P_BITS) - 5  # 67108859, prime
_MASK26 = (1 << P_BITS) - 1
_MASK13 = (1 << 13) - 1
_MASK7 = (1 << 7) - 1

FIELD_DTYPE = jnp.int32


def _csub(t):
    """Conditional subtract: t in [0, 2p) -> t mod p."""
    return t - jnp.where(t >= P, P, 0).astype(t.dtype)


def fold26(t):
    """Reduce t in [0, 2^31) to [0, p) using 2^26 = 5 (mod p).

    t = t1 * 2^26 + t0  ==>  t = 5*t1 + t0 (mod p).
    For t < 2^31: t1 < 2^5 so 5*t1 + t0 < 2^26 + 160 < 2p; one csub finishes.
    """
    t1 = jax.lax.shift_right_logical(t, P_BITS)
    t0 = jnp.bitwise_and(t, _MASK26)
    return _csub(t1 * 5 + t0)


# Barrett reduction against p = 2^26 - 5.  mu = floor(2^32 / p) = 64 = 2^6
# EXACTLY (2^32 = 64*p + 320), so the Barrett quotient
#   q = (t * mu) >> 32  =  (t << 6) >> 32  =  t >> 26
# needs no 64-bit multiply: mu folds into a single shift.  The classic
# Barrett error bound gives q in {floor(t/p)-1, floor(t/p)} for t < 2^31
# (the gap t/p - t/2^26 = 5t/(p*2^26) < 1 over the whole range), hence
# r = t - q*p lies in [0, 2p) and one conditional subtract finishes.
BARRETT_MU = (1 << 32) // P          # 64 == 2^6, public constant
_BARRETT_SHIFT = 32 - (BARRETT_MU.bit_length() - 1)   # 26


def barrett_reduce(t):
    """Barrett-reduce t in [0, 2^31) to [0, p).

    q = (t * BARRETT_MU) >> 32 computed as a shift (mu is a power of two
    for this p); r = t - q*p < 2p, one csub.  Sanctioned field-arithmetic
    site: the mu-multiply/shift + q*p subtract is the reduction itself.
    """
    q = jax.lax.shift_right_logical(t, _BARRETT_SHIFT)
    return _csub(t - q * P)


def add(a, b):
    """(a + b) mod p.  a, b in [0, p): sum < 2^27, fits int32."""
    return _csub(a + b)


def sub(a, b):
    """(a - b) mod p."""
    d = a - b
    return d + jnp.where(d < 0, P, 0).astype(d.dtype)


def neg(a):
    """(-a) mod p."""
    return _csub(jnp.asarray(P, a.dtype) - a)


def mul(a, b):
    """(a * b) mod p via 13-bit limbs -- every intermediate < 2^31.

    a = a1*2^13 + a0, b = b1*2^13 + b0 with a1,b1 < 2^13, a0,b0 < 2^13.
      a*b = a1*b1*2^26 + (a1*b0 + a0*b1)*2^13 + a0*b0
    Let mm = a1*b0 + a0*b1 < 2^27; mm = m1*2^13 + m0 (m1 < 2^14).
      mm*2^13 = m1*2^26 + m0*2^13 == 5*m1 + m0*2^13 (mod p)
    Total t = 5*hh + 5*m1 + (m0<<13) + ll
            < 5*2^26 + 5*2^14 + 2^26 + 2^26 < 2^29.4 < 2^31.  fold26 + csub.
    """
    a1 = jax.lax.shift_right_logical(a, 13)
    a0 = jnp.bitwise_and(a, _MASK13)
    b1 = jax.lax.shift_right_logical(b, 13)
    b0 = jnp.bitwise_and(b, _MASK13)
    hh = a1 * b1
    mm = a1 * b0 + a0 * b1
    ll = a0 * b0
    m1 = jax.lax.shift_right_logical(mm, 13)
    m0 = jnp.bitwise_and(mm, _MASK13)
    t = 5 * hh + 5 * m1 + jax.lax.shift_left(m0, 13) + ll
    return fold26(t)


def mul_scalar(a, c: int):
    """a * c mod p where c is a static Python int (public constant)."""
    c = int(c) % P
    return mul(a, jnp.asarray(c, a.dtype))


def pow_const(a, e: int):
    """a ** e mod p for a static exponent, by square-and-multiply."""
    e = int(e)
    assert e >= 0
    result = jnp.ones_like(a)
    base = a
    while e:
        if e & 1:
            result = mul(result, base)
        base = mul(base, base)
        e >>= 1
    return result


def inv(a):
    """a^{-1} mod p (Fermat).  Undefined for a == 0."""
    return pow_const(a, P - 2)


# ---------------------------------------------------------------------------
# Host-side exact helpers (used for static public constants such as the
# Lagrange coefficient matrices -- evaluation points are public).
# ---------------------------------------------------------------------------

def host_inv(a: int) -> int:
    return pow(int(a) % P, P - 2, P)


def host_lagrange_coeffs(xs, targets) -> np.ndarray:
    """Exact Lagrange basis matrix  L[t, j] = prod_{l != j} (z_t - x_l)/(x_j - x_l)
    over F_p, computed with Python ints.  xs: interpolation nodes (len n);
    targets: evaluation points (len m).  Returns (m, n) int32 in [0, p).
    """
    xs = [int(x) % P for x in xs]
    ts = [int(t) % P for t in targets]
    n = len(xs)
    out = np.zeros((len(ts), n), dtype=np.int64)
    for ti, z in enumerate(ts):
        for j in range(n):
            num, den = 1, 1
            for l in range(n):
                if l == j:
                    continue
                num = (num * ((z - xs[l]) % P)) % P
                den = (den * ((xs[j] - xs[l]) % P)) % P
            out[ti, j] = (num * host_inv(den)) % P
    return out.astype(np.int32)


# ---------------------------------------------------------------------------
# Field matmul: the 7-bit-limb / f32-MXU algorithm (also used by the Pallas
# kernel, block-wise).  Pure jnp version here for small/irregular shapes and
# as a shared reference.
# ---------------------------------------------------------------------------

_N_LIMBS = 4  # 4 x 7-bit limbs cover 28 >= 26 bits
_LIMB_BITS = 7
# 2^(7*(i+j)) mod p for i+j in [0, 6]
_LIMB_WEIGHTS = tuple(pow(2, _LIMB_BITS * s, P) for s in range(2 * _N_LIMBS - 1))
# max contraction length for exact f32 accumulation: products < 2^14, f32 is
# exact below 2^24  =>  chunk <= 2^10
MATMUL_CHUNK = 1 << 10


def _limbs(x):
    """int32 [0,p) -> f32 limbs stacked on a new leading axis (4, ...)."""
    ls = []
    for i in range(_N_LIMBS):
        ls.append(jnp.bitwise_and(
            jax.lax.shift_right_logical(x, _LIMB_BITS * i), _MASK7))
    return jnp.stack(ls).astype(jnp.float32)


def _lazy_shift26(h, b: int):
    """h * 2^b (mod p) as an UNREDUCED int32 value, b in [0, 26).

    Split h = h1 * 2^(26-b) + h0 (h0 < 2^(26-b)); then
      h * 2^b = h1 * 2^26 + h0 * 2^b == 5*h1 + h0 * 2^b  (mod p).
    The result is exact mod p but deliberately NOT reduced -- callers
    accumulate several lazy terms and Barrett-reduce once.  Bound:
    for h < 2^(26+c), result < 5*2^(b+c) + 2^26.
    """
    h1 = jax.lax.shift_right_logical(h, P_BITS - b)
    h0 = jnp.bitwise_and(h, (1 << (P_BITS - b)) - 1)
    return h1 * 5 + jax.lax.shift_left(h0, b)


def recombine_limb_groups(groups):
    """Mod-p combination  sum_s groups[s] * 2^(7s)  with ONE final reduce.

    groups: 7 int32 arrays G_s < 2^26 (group s collects the limb-pair
    partial sums with i+j == s: <= 4 terms, each <= 1024*127*127 < 2^24,
    so G_s <= 66,064,384 < 2^26).  Every weight 2^(7s) mod p is applied
    lazily -- static shift/splits via 2^26 == 5 (s <= 3), a plain *20
    (s == 4, since 2^28 == 20 mod p), or *5 then shift-split (s in {5,6})
    -- so no per-term reduction happens at all.  Worst-case total:
      G_0 + (2^26 + 5*2^7) + (2^26 + 5*2^14) + (2^26 + 5*2^21)
        + 20*G_4 + (2^26 + 5*2^11) + (2^26 + 5*2^17)
      <= 1.36e9 < 2^31,
    (the dominant term is 20*G_4 <= 990,965,760), so a single
    barrett_reduce finishes.  This replaces the historical 16x
    fold26+mul+add per-term chain.
    """
    t = groups[0]                                   # w = 1
    t = t + _lazy_shift26(groups[1], 7)             # w = 2^7
    t = t + _lazy_shift26(groups[2], 14)            # w = 2^14
    t = t + _lazy_shift26(groups[3], 21)            # w = 2^21
    t = t + groups[4] * 20                          # 2^28 == 20 (mod p)
    t = t + _lazy_shift26(groups[5] * 5, 9)         # 2^35 == 5 * 2^9
    t = t + _lazy_shift26(groups[6] * 5, 16)        # 2^42 == 5 * 2^16
    return barrett_reduce(t)


def _recombine_limb_products(s):
    """s: (4, 4, M, N) f32 exact-int partial sums (< 2^24).

    Returns (M, N) int32 mod-p recombination  sum_ij s[i,j] * 2^(7(i+j)).
    Partial sums sharing a weight class s = i+j are grouped in int32
    FIRST (f32 sums could cross the 2^24 exact-integer bound), then the
    whole recombination is one Barrett reduce via recombine_limb_groups.
    """
    groups = [None] * (2 * _N_LIMBS - 1)
    for i in range(_N_LIMBS):
        for j in range(_N_LIMBS):
            term = s[i, j].astype(jnp.int32)
            g = groups[i + j]
            groups[i + j] = term if g is None else g + term
    return recombine_limb_groups(groups)


def matmul(a, b):
    """(a @ b) mod p for int32 field matrices a:(M,K), b:(K,N).

    TPU-native: 16 exact f32 matmuls per <=1024-wide K-chunk + int32 modular
    recombination.  No intermediate exceeds f32's exact-int range or int32.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out = jnp.zeros((m, n), dtype=jnp.int32)
    for start in range(0, k, MATMUL_CHUNK):
        stop = min(start + MATMUL_CHUNK, k)
        al = _limbs(a[:, start:stop])          # (4, M, kc)
        bl = _limbs(b[start:stop, :])          # (4, kc, N)
        # s[i, j] = A_i @ B_j, exact in f32 (products < 2^14, kc <= 2^10)
        s = jnp.einsum("imk,jkn->ijmn", al, bl,
                       preferred_element_type=jnp.float32)
        out = add(out, _recombine_limb_products(s))
    return out


def matvec(a, v):
    """(a @ v) mod p, a:(M,K) v:(K,)."""
    return matmul(a, v[:, None])[:, 0]


def matvec_batched(a, v):
    """(a[i] @ v[i]) mod p for a: (B, M, K), v: (B, K) -- limb-packed GEMM.

    A vmap of matvec runs 16 (M, kc) x (kc, 1) limb matvecs per batch
    element; packing the 4 limbs of `a` into the GEMM M dimension and the 4
    limbs of `v` into its N dimension turns each K-chunk into ONE
    (B, 4M, kc) x (B, kc, 4) batched matmul -- a far better gemm shape than
    n=1 matvecs (1.25x over the vmap at B=8, 2.6x at B=32 on XLA CPU), with
    identical recombination cost.  Exactness bounds are unchanged: products
    < 2^14 accumulated over kc <= 2^10 stay in f32's exact-integer range.
    """
    bsz, m, k = a.shape
    assert v.shape == (bsz, k), (a.shape, v.shape)
    out = jnp.zeros((bsz, m), jnp.int32)
    for start in range(0, k, MATMUL_CHUNK):
        stop = min(start + MATMUL_CHUNK, k)
        al = jax.vmap(_limbs)(a[:, :, start:stop])       # (B, 4, M, kc)
        vl = jax.vmap(_limbs)(v[:, start:stop])          # (B, 4, kc)
        s = jnp.matmul(al.reshape(bsz, _N_LIMBS * m, stop - start),
                       jnp.swapaxes(vl, 1, 2),
                       preferred_element_type=jnp.float32)
        s = s.reshape(bsz, _N_LIMBS, m, _N_LIMBS)        # (B, i, M, j)
        out = add(out, _recombine_limb_products(
            jnp.transpose(s, (1, 3, 0, 2))))             # (i, j, B, M)
    return out


def evaluate_poly(coeffs, x):
    """Horner evaluation of sum_i coeffs[i] * x^i over F_p.

    coeffs: 1-D int32 field array, lowest degree first.  x: any shape.
    """
    acc = jnp.full_like(x, int(coeffs[-1]))
    for i in range(coeffs.shape[0] - 2, -1, -1):
        acc = add(mul(acc, x), jnp.full_like(x, int(coeffs[i])))
    return acc


def evaluate_poly_dyn(coeffs, x):
    """Horner with traced coefficient vector (not static)."""
    acc = jnp.broadcast_to(coeffs[-1], x.shape)
    for i in range(coeffs.shape[0] - 2, -1, -1):
        acc = add(mul(acc, x), jnp.broadcast_to(coeffs[i], x.shape))
    return acc


def random_field(key, shape):
    """Uniform elements of F_p."""
    return jax.random.randint(key, shape, 0, P, dtype=FIELD_DTYPE)


# ---------------------------------------------------------------------------
# numpy uint64 oracle (host-side ground truth for tests; NOT part of the
# TPU-lowerable path)
# ---------------------------------------------------------------------------

def np_mul(a, b):
    return ((a.astype(np.uint64) * b.astype(np.uint64)) % np.uint64(P)).astype(np.int64)


def np_matmul(a, b):
    """Exact field matmul with the paper's 64-bit lazy reduction."""
    a = a.astype(np.uint64)
    b = b.astype(np.uint64)
    k = a.shape[1]
    # d*(p-1)^2 <= 2^64-1 holds for d <= 4096 with this p; chunk to stay safe
    chunk = 4096
    out = np.zeros((a.shape[0], b.shape[1]), dtype=np.uint64)
    for s in range(0, k, chunk):
        out = (out + (a[:, s:s + chunk] @ b[s:s + chunk, :]) % np.uint64(P)) % np.uint64(P)
    return out.astype(np.int64)
