"""Benchmark protocols from the paper's Section V.

1. float_logreg       -- conventional logistic regression (Fig. 4 baseline).
2. MpcBaseline        -- the [BGW88]/[BH08] MPC training baselines with the
   paper's subgroup optimization (Appendix D): clients are split into G=3
   subgroups; subgroup i holds Shamir shares of one third of X and computes
   its sub-gradient *entirely in the share domain* -- every matmul and the
   polynomial sigmoid require secure multiplications with degree reduction,
   which is exactly the communication the paper's Table I shows dominating.

The MPC baseline shares COPML's quantization/truncation machinery so the
accuracy comparison isolates the *protocol* difference, as in the paper.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import field, mpc, objectives, quantize, shamir, sigmoid_approx, \
    truncation
from .labels import Opened, Share
from .protocol import CopmlConfig  # noqa: F401  (re-exported for callers)


def sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


def float_logreg(x, y, eta: float, iters: int, callback=None):
    """Conventional full-batch GD logistic regression (paper Fig. 4)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m, d = x.shape
    w = np.zeros(d)
    for t in range(iters):
        w -= eta / m * (x.T @ (sigmoid(x @ w) - y))
        if callback is not None:
            callback(t, w)
    return w


def float_poly_logreg(x, y, eta: float, iters: int, r: int = 1,
                      bound: float = 10.0, callback=None):
    """Float GD with the degree-r polynomial sigmoid -- isolates the
    approximation error from the quantization error."""
    coeffs = sigmoid_approx.fit_sigmoid_poly(r, bound)
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m, d = x.shape
    w = np.zeros(d)
    for t in range(iters):
        ghat = sigmoid_approx.poly_eval_float(coeffs, x @ w)
        w -= eta / m * (x.T @ (ghat - y))
        if callback is not None:
            callback(t, w)
    return w


def _float_scan(x, y, eta: float, iters: int, ghat_fn, history: bool):
    """Shared lax.scan float trainer: the jit engine for the float
    protocols.  float32 on-device, so it tracks the float64 numpy loops to
    accuracy (not bit-) tolerance."""
    xj = jnp.asarray(x, jnp.float32)
    yj = jnp.asarray(y, jnp.float32)
    m, d = xj.shape

    def body(w, _):
        g = xj.T @ (ghat_fn(xj @ w) - yj)
        w = w - (eta / m) * g
        return w, (w if history else None)

    return jax.lax.scan(body, jnp.zeros((d,), jnp.float32), None,
                        length=iters)


@partial(jax.jit, static_argnames=("eta", "iters", "history"))
def float_logreg_scan(x, y, eta: float, iters: int, history: bool = True):
    """float_logreg as one compiled lax.scan; (w, history-or-None)."""
    return _float_scan(x, y, eta, iters, jax.nn.sigmoid, history)


@partial(jax.jit, static_argnames=("eta", "iters", "r", "bound", "history"))
def float_poly_logreg_scan(x, y, eta: float, iters: int, r: int = 1,
                           bound: float = 10.0, history: bool = True):
    """float_poly_logreg as one compiled lax.scan; (w, history-or-None)."""
    coeffs = sigmoid_approx.fit_sigmoid_poly(r, bound)

    def ghat(z):
        acc = jnp.full_like(z, float(coeffs[-1]))
        for c in coeffs[-2::-1]:
            acc = acc * z + float(c)
        return acc

    return _float_scan(x, y, eta, iters, ghat, history)


# -------------------------------------------- objective-generic float GD
#
# The logreg-named trainers above predate the SecureObjective split and
# stay as-is (they back the paper's Fig.-4 comparisons and their compiled
# programs are cached across the suite).  The generic pair below drives
# the float / poly_float protocols for every OTHER objective: the model
# may be a (d,) vector or a (d, C) matrix; the gradient is always
# X^T (g(XW) - Y) / m with g the exact activation or its degree-r
# polynomial fit, columnwise -- the float twin of the coded pipeline.


def float_objective_train(obj, x, y, eta: float, iters: int, callback=None,
                          *, poly: bool = False, r: int = 1,
                          bound: float = 10.0):
    """Plaintext GD for any SecureObjective (numpy float64 loop)."""
    x = np.asarray(x, np.float64)
    targets = np.asarray(obj.prepare_targets(y), np.float64)
    m = x.shape[0]
    coeffs = obj.float_coeffs(r, bound) if poly else None
    w = np.zeros(obj.w_shape(x.shape[1]))
    for t in range(iters):
        z = x @ w
        g = sigmoid_approx.poly_eval_float(coeffs, z) if poly \
            else obj.act_np(z)
        w = w - eta / m * (x.T @ (g - targets))
        if callback is not None:
            callback(t, w)
    return w


def float_objective_scan(obj, x, y, eta: float, iters: int,
                         history: bool = True, *, poly: bool = False,
                         r: int = 1, bound: float = 10.0):
    """float_objective_train as one compiled lax.scan (float32 on-device);
    returns (w, history-or-None).  `obj` is static (hashable frozen
    dataclass), so each objective compiles once.  Target preparation
    (e.g. one-hot) is host-side numpy, hence outside the jit."""
    targets = np.asarray(obj.prepare_targets(y), np.float32)
    return _float_objective_jit(obj, jnp.asarray(x, jnp.float32),
                                jnp.asarray(targets), float(eta), int(iters),
                                bool(history), bool(poly), int(r),
                                float(bound))


@partial(jax.jit, static_argnames=("obj", "eta", "iters", "history", "poly",
                                   "r", "bound"))
def _float_objective_jit(obj, xj, yj, eta: float, iters: int,
                         history: bool, poly: bool, r: int, bound: float):
    m = xj.shape[0]
    coeffs = obj.float_coeffs(r, bound) if poly else None

    def g_fn(z):
        if not poly:
            return obj.act_jnp(z)
        acc = jnp.full_like(z, float(coeffs[-1]))
        for c in coeffs[-2::-1]:
            acc = acc * z + float(c)
        return acc

    def body(w, _):
        w = w - (eta / m) * (xj.T @ (g_fn(xj @ w) - yj))
        return w, (w if history else None)

    w0 = jnp.zeros(obj.w_shape(xj.shape[1]), jnp.float32)
    return jax.lax.scan(body, w0, None, length=iters)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MpcState:
    w_shares: Share            # (N_g, d, C') model shares (all groups share)
    x_shares: Share            # (G, N_g, m/G, d) per-subgroup data shares
    xty_shares: Share          # (G, N_g, d, C')
    step: jnp.ndarray | int = 0


class MpcBaseline:
    """Secret-shared GD per Appendix D (G subgroups), objective-generic.

    The model always carries a trailing output axis C' (= 1 for the vector
    objectives, C for multi-class one-vs-rest), so every secure matmul and
    the share-domain Horner chain are written once; the binary path draws
    the same randomness volume per call as the pre-objective code."""

    def __init__(self, cfg: CopmlConfig, m: int, d: int, groups: int = 3,
                 scheme: str = "bh08", objective=None):
        self.cfg, self.m, self.d, self.g = cfg, m, d, groups
        self.obj = objectives.BINARY_LOGISTIC if objective is None \
            else objective
        self.obj.validate_cfg(cfg)
        self.c_out = self.obj.n_outputs          # trailing model axis C'
        self.n_g = cfg.n_clients // groups      # clients per subgroup
        assert self.n_g >= 2 * cfg.t + 1, "subgroup too small for 2T+1"
        self.lambdas = tuple(range(1, self.n_g + 1))
        self.q_eta, self.e, self.k1, self.k2 = self.obj.update_constants(
            cfg, m)
        self.poly_coeffs = self.obj.field_coeffs(cfg)
        self._mul = mpc.mul_bh08 if scheme == "bh08" else mpc.mul_bgw
        self.scheme = scheme

    def setup(self, key, x, y) -> MpcState:
        cfg = self.cfg
        per = self.m // self.g
        keys = jax.random.split(key, 2 * self.g + 1)
        xq = quantize.quantize(jnp.asarray(x[: per * self.g]), cfg.lx)
        targets = self.obj.prepare_targets(np.asarray(y)[: per * self.g])
        yq = quantize.quantize(jnp.asarray(targets, jnp.float32), cfg.lg)
        xg = xq.reshape(self.g, per, self.d)
        yg = yq.reshape((self.g, per) + self.obj.out_shape)
        x_shares, xty = [], []
        for gi in range(self.g):
            xs = shamir.share(keys[2 * gi], xg[gi], cfg.t, self.n_g,
                              self.lambdas)
            ys = shamir.share(keys[2 * gi + 1], yg[gi], cfg.t, self.n_g,
                              self.lambdas)
            ys_mat = ys if self.obj.out_shape else ys[..., None]
            x_shares.append(xs)
            xty.append(self._mul(
                keys[2 * gi], jnp.swapaxes(xs, 1, 2), ys_mat,
                cfg.t, matmul=True, points=self.lambdas))  # (N_g, d, C')
        w = shamir.share(keys[-1],
                         jnp.zeros((self.d, self.c_out), field.FIELD_DTYPE),
                         cfg.t, self.n_g, self.lambdas)
        return MpcState(w_shares=w, x_shares=jnp.stack(x_shares),
                        xty_shares=jnp.stack(xty))

    def iteration(self, key, state: MpcState) -> MpcState:
        """One GD step fully in the share domain (per subgroup), then
        aggregate sub-gradients (local add) and secure-truncate-update."""
        cfg = self.cfg
        keys = jax.random.split(key, self.g + 1)
        grad_shares = None
        for gi in range(self.g):
            xs = state.x_shares[gi]                       # (N_g, mG, d)
            # Z = X W : secure matmul (degree reduction!), all C' columns
            z = self._mul(keys[gi], xs, state.w_shares, cfg.t, matmul=True,
                          points=self.lambdas)            # (N_g, mG, C')
            # ghat(Z) in the share domain: Horner => r secure mults
            acc = jnp.full_like(z, int(self.poly_coeffs[-1]))
            for ci in range(len(self.poly_coeffs) - 2, -1, -1):
                acc = self._mul(jax.random.fold_in(keys[gi], ci), acc, z,
                                cfg.t, points=self.lambdas)
                acc = mpc.add_public(acc, int(self.poly_coeffs[ci]))
            # X^T ghat : secure matmul
            xtg = self._mul(jax.random.fold_in(keys[gi], 99),
                            jnp.swapaxes(xs, 1, 2), acc,
                            cfg.t, matmul=True,
                            points=self.lambdas)          # (N_g, d, C')
            g_sh = field.sub(xtg, state.xty_shares[gi])
            grad_shares = g_sh if grad_shares is None else field.add(
                grad_shares, g_sh)
        scaled = field.mul_scalar(grad_shares, self.q_eta)
        delta = truncation.trunc_pr(keys[-1], scaled, self.k1, self.k2,
                                    cfg.t, self.lambdas)
        return dataclasses.replace(
            state, w_shares=field.sub(state.w_shares, delta),
            step=state.step + 1)

    def train(self, key, x, y, iters: int, callback=None):
        ks, ki = jax.random.split(key)
        state = self.setup(ks, x, y)
        step = self._jitted_step()
        for t in range(iters):
            state = step(jax.random.fold_in(ki, t), state)
            if callback is not None:
                callback(t, self.open_model(state))
        return state, self.open_model(state)

    def train_scan(self, key, x, y, iters: int, history: bool = False):
        """train() as ONE compiled lax.scan -- the facade's jit engine.

        Same key schedule as the eager loop (fold_in per step), so the two
        engines are bit-exact.  Returns (state, w[, history])."""
        ks, ki = jax.random.split(key)
        state = self.setup(ks, x, y)
        state, hist = _mpc_scan(self, ki, state, int(iters), bool(history))
        w = self.open_model(state)
        return (state, w, hist) if history else (state, w)

    def _jitted_step(self):
        if "_step" not in self.__dict__:
            self._step = jax.jit(self.iteration)
        return self._step

    def open_model(self, state: MpcState) -> Opened:
        w = mpc.open_shares(state.w_shares, self.cfg.t, self.lambdas)
        w = quantize.dequantize(w, self.cfg.lw)       # (d, C')
        return w[..., 0] if not self.obj.out_shape else w


@partial(jax.jit, static_argnames=("mb", "iters", "history"))
def _mpc_scan(mb: MpcBaseline, key, state: MpcState, iters: int,
              history: bool):
    """lax.scan over MPC-baseline iterations (mirror of
    protocol._scan_iterations; `mb` is static, hashed by identity)."""

    def body(st, t):
        st = mb.iteration(jax.random.fold_in(key, t), st)
        return st, (mb.open_model(st) if history else None)

    return jax.lax.scan(body, state, jnp.arange(iters))
