"""Mesh-aware sharding helpers, usable from any layer.

Two families live here:

* GSPMD annotation (`maybe_constrain`): soft sharding hints that XLA may
  honor; the same code runs unsharded on a laptop.
* The explicit client mesh (`client_mesh`, `psum_scatter_mod`,
  `all_gather_clients`, `all_to_all_clients`): the shard_map substrate of the
  distributed COPML engine (protocol.Copml.train_sharded), where the client
  axis of every share array is physically split over a 1-D ("clients",) mesh
  and the protocol's EXCHANGE/OPEN steps are real collectives.

The mod-p reductions exploit that field elements are canonical in [0, p):
a raw int32 psum of D partial sums stays below D * p < 2^31 for D <= 31,
so one fold26 after the collective restores the canonical representative --
bit-identical to computing the same contraction on one device.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported.

    jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
    newer JAX; on older versions every axis is implicitly Auto, so omitting
    the kwarg is semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def set_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh where it exists,
    the legacy Mesh context (which is its own context manager and equally
    enables bare-PartitionSpec sharding constraints) on older JAX."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def _active_mesh():
    """The ambient mesh, or None: get_abstract_mesh on new JAX, the
    thread-resources physical mesh set by the Mesh context on old JAX."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def maybe_constrain(x, *spec):
    """with_sharding_constraint iff a usable mesh is active (set_mesh above).

    Axes absent from the mesh or not dividing the dim are dropped, so the
    same code runs on a laptop and on the 512-chip production mesh."""
    try:
        mesh = _active_mesh()
    except Exception:   # noqa: BLE001
        return x
    if mesh is None or not mesh.shape:
        return x
    fixed = []
    for dim, entry in zip(x.shape, spec + (None,) * (x.ndim - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in entries if a in mesh.shape)
        size = 1
        for a in kept:
            size *= mesh.shape[a]
        if not kept or dim % size:
            fixed.append(None)
        else:
            fixed.append(kept if len(kept) > 1 else kept[0])
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*fixed))


CLIENTS = ("clients", "pod", "data", "model")   # COPML client axis spans the mesh

# name of the 1-D mesh axis the distributed engine shards clients over
CLIENT_AXIS = "clients"

# raw int32 psum of canonical field elements must not wrap: D * (p-1) < 2^31.
# Wider meshes switch to the two-limb reduction (see _reduce_mod), exact for
# any realistic shard count.
NARROW_SHARDS = 31


def client_mesh(n_devices: int | None = None, devices=None):
    """1-D ("clients",) mesh over (a prefix of) the host's devices.

    This is the mesh Copml.train_sharded runs on; on a CPU host expose
    multiple devices with XLA_FLAGS=--xla_force_host_platform_device_count=8
    (set BEFORE the first jax import).  Unlike make_mesh this accepts a
    device subset, so one 8-device process can build 4- and 8-way meshes.
    """
    devs = list(jax.devices()) if devices is None else list(devices)
    if n_devices is not None:
        assert n_devices <= len(devs), (n_devices, len(devs))
        devs = devs[:n_devices]
    return jax.sharding.Mesh(np.array(devs), (CLIENT_AXIS,))


def _reduce_mod(x, nshards, reducer):
    """Exact mod-p cross-shard reduction of canonical field elements.

    nshards <= NARROW_SHARDS: one raw int32 reduction (sum < D*p < 2^31),
    one fold26.  Wider: reduce the 13-bit halves separately (sums < D*2^13,
    safe to D = 2^17) and recombine with field ops -- two collectives, still
    the same canonical value because everything is mod-p linear.
    """
    from . import field
    if nshards <= NARROW_SHARDS:
        return field.fold26(reducer(x))
    lo = jnp.bitwise_and(x, (1 << 13) - 1)
    hi = jax.lax.shift_right_logical(x, 13)
    return field.add(field.mul_scalar(field.fold26(reducer(hi)), 1 << 13),
                     field.fold26(reducer(lo)))


def psum_scatter_mod(x, axis_name: str = CLIENT_AXIS,
                     nshards: int | None = None):
    """Mod-p reduce-scatter over the leading axis (must divide evenly)."""
    return _reduce_mod(x, nshards or NARROW_SHARDS + 1,
                       lambda v: jax.lax.psum_scatter(
                           v, axis_name, scatter_dimension=0, tiled=True))


def all_gather_clients(x, axis_name: str = CLIENT_AXIS):
    """Concatenate every shard's leading axis in device order (OPEN step)."""
    return jax.lax.all_gather(x, axis_name, axis=0, tiled=True)


def all_to_all_clients(x, axis_name: str = CLIENT_AXIS):
    """Owner<->holder transpose (EXCHANGE step): split the leading (holder)
    axis across shards, concatenate the received blocks on axis 1 (owner).
    (n_pad, n_loc, ...) per shard -> (n_loc, n_pad, ...) per shard."""
    return jax.lax.all_to_all(x, axis_name, split_axis=0, concat_axis=1,
                              tiled=True)


# --------------------------------------------------------------------------
# Ring (ppermute-chained) forms of the two EXCHANGE collectives.
#
# Monolithic psum_scatter / all_to_all force the WHOLE local contraction to
# finish before any byte moves.  The ring forms take a `segment_fn(j)` /
# `block_fn(j)` producing only shard j's slice of the local result, so each
# hop's operand is computed just before its ppermute -- the GEMM for
# segment j+1 has no data dependence on hop j and XLA is free to overlap
# compute with the in-flight transfer.  Both are bit-exact with their
# monolithic twins: segment values are the same canonical field elements
# (a row slice of a matmul is the same contraction), the ring's raw int32
# accumulation is the same no-overflow integer sum in a different order,
# and the single trailing fold26 matches _reduce_mod's narrow path.


def ring_reduce_scatter_mod(segment_fn, axis_name: str, ndev: int):
    """Mod-p reduce-scatter as a D-1 hop ring; shard r ends with
    fold26(sum_s segment_fn_of_shard_s(r)).

    segment_fn(j) -> this shard's canonical-field partial destined for
    shard j (j traced).  Requires ndev <= NARROW_SHARDS (raw int32 sum of D
    canonical elements must not wrap); callers fall back to
    psum_scatter_mod beyond that.
    """
    from . import field
    assert ndev <= NARROW_SHARDS, ndev
    r = jax.lax.axis_index(axis_name)
    if ndev == 1:
        return field.fold26(segment_fn(r))
    perm = [(i, (i + 1) % ndev) for i in range(ndev)]
    # shard r's chunk travels the whole ring: start with the partial for
    # destination r-1 (which r sends first), finish holding destination r
    acc = segment_fn((r + ndev - 1) % ndev)
    for k in range(ndev - 1):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + segment_fn((r + ndev - k - 2) % ndev)
    return field.fold26(acc)


def ring_all_to_all(block_fn, axis_name: str, ndev: int):
    """Owner<->holder transpose as D-1 ppermute hops; bit-exact with
    all_to_all_clients applied to the stacked blocks.

    block_fn(j) -> this shard's (n_loc, ...) block destined for shard j
    (j traced), i.e. rows j*n_loc..(j+1)*n_loc of the monolithic operand.
    Each block is computed just before its hop.  Returns the received
    blocks stacked on a NEW leading axis in SOURCE-shard order (shard s's
    block at index s) -- shape (ndev, n_loc, ...).
    """
    r = jax.lax.axis_index(axis_name)
    received = [block_fn(r)]                      # own block, k = 0
    for k in range(1, ndev):
        perm = [(i, (i + k) % ndev) for i in range(ndev)]
        received.append(jax.lax.ppermute(block_fn((r + k) % ndev),
                                         axis_name, perm))
    stacked = jnp.stack(received)                 # index k <- shard (r-k)%D
    # reorder k-major to source-shard-major: source s sits at k = (r-s)%D
    return jnp.take(stacked, (r - jnp.arange(ndev)) % ndev, axis=0)
