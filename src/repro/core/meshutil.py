"""Mesh-aware sharding constraint helper, usable from any layer."""

from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """jax.make_mesh with explicit Auto axis types where supported.

    jax.sharding.AxisType (and make_mesh's axis_types kwarg) only exist in
    newer JAX; on older versions every axis is implicitly Auto, so omitting
    the kwarg is semantically identical."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def set_mesh(mesh):
    """Context manager activating `mesh`: jax.set_mesh where it exists,
    the legacy Mesh context (which is its own context manager and equally
    enables bare-PartitionSpec sharding constraints) on older JAX."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    return mesh


def _active_mesh():
    """The ambient mesh, or None: get_abstract_mesh on new JAX, the
    thread-resources physical mesh set by the Mesh context on old JAX."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        return getter()
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    return None if m.empty else m


def maybe_constrain(x, *spec):
    """with_sharding_constraint iff a usable mesh is active (set_mesh above).

    Axes absent from the mesh or not dividing the dim are dropped, so the
    same code runs on a laptop and on the 512-chip production mesh."""
    try:
        mesh = _active_mesh()
    except Exception:   # noqa: BLE001
        return x
    if mesh is None or not mesh.shape:
        return x
    fixed = []
    for dim, entry in zip(x.shape, spec + (None,) * (x.ndim - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in entries if a in mesh.shape)
        size = 1
        for a in kept:
            size *= mesh.shape[a]
        if not kept or dim % size:
            fixed.append(None)
        else:
            fixed.append(kept if len(kept) > 1 else kept[0])
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*fixed))


CLIENTS = ("pod", "data", "model")   # the COPML client axis spans the mesh
