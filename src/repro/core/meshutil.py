"""Mesh-aware sharding constraint helper, usable from any layer."""

from __future__ import annotations

import jax


def maybe_constrain(x, *spec):
    """with_sharding_constraint iff a usable mesh is active (jax.set_mesh).

    Axes absent from the mesh or not dividing the dim are dropped, so the
    same code runs on a laptop and on the 512-chip production mesh."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:   # noqa: BLE001
        return x
    if mesh is None or not mesh.shape:
        return x
    fixed = []
    for dim, entry in zip(x.shape, spec + (None,) * (x.ndim - len(spec))):
        if entry is None:
            fixed.append(None)
            continue
        entries = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(a for a in entries if a in mesh.shape)
        size = 1
        for a in kept:
            size *= mesh.shape[a]
        if not kept or dim % size:
            fixed.append(None)
        else:
            fixed.append(kept if len(kept) > 1 else kept[0])
    from jax.sharding import PartitionSpec as P
    return jax.lax.with_sharding_constraint(x, P(*fixed))


CLIENTS = ("pod", "data", "model")   # the COPML client axis spans the mesh
