"""Degree-r least-squares polynomial approximation of the sigmoid (Eq. 5).

The paper fits ghat(z) = sum_i c_i z^i by least squares on an interval and
finds r=1 already gives accuracy parity (Section V).  We fit on a uniform
grid over [-B, B] and also expose the quantized field coefficients used
inside the protocol.
"""

from __future__ import annotations

from functools import lru_cache

import numpy as np

from . import field


def sigmoid(z):
    return 1.0 / (1.0 + np.exp(-z))


@lru_cache(maxsize=None)
def fit_sigmoid_poly(r: int, bound: float = 10.0, n_grid: int = 2001) -> tuple:
    """Least-squares coefficients c_0..c_r (floats, lowest degree first)."""
    z = np.linspace(-bound, bound, n_grid)
    v = np.vander(z, r + 1, increasing=True)
    coeffs, *_ = np.linalg.lstsq(v, sigmoid(z), rcond=None)
    return tuple(float(c) for c in coeffs)


def poly_eval_float(coeffs, z):
    out = np.zeros_like(z, dtype=np.float64)
    for c in reversed(coeffs):
        out = out * z + c
    return out


def max_abs_error(r: int, bound: float = 10.0) -> float:
    z = np.linspace(-bound, bound, 4001)
    c = fit_sigmoid_poly(r, bound)
    return float(np.max(np.abs(poly_eval_float(c, z) - sigmoid(z))))


def quantized_coeffs(r: int, lx: int, degree_scales, bound: float = 10.0) -> np.ndarray:
    """Field-embedded coefficients for Horner evaluation on quantized inputs.

    If the argument z arrives quantized with scale 2^{sz} (sz =
    degree_scales), then evaluating sum c_i z^i in the field with
    coefficients  c_i * 2^{lx_out - i*sz}  yields the result at scale
    2^{lx_out}.  Caller supplies per-degree scale exponents
    degree_scales = [lx_out - i*sz for i in 0..r]; entries must be >= 0
    (choose lx_out large enough).
    """
    cs = fit_sigmoid_poly(r, bound)
    out = []
    for c, s in zip(cs, degree_scales):
        assert s >= 0, "negative coefficient scale; increase lx_out"
        q = int(round(c * (1 << s)))
        out.append(q % field.P)
    return np.asarray(out, dtype=np.int32)
