"""Lagrange Coded Computing (LCC) encode/decode -- the heart of COPML.

Dataset X (quantized, in F_p) is partitioned into K row-blocks X_1..X_K.
With T random mask blocks Z_{K+1}..Z_{K+T}, the Lagrange polynomial

    u(z) = sum_k X_k * l_k(z) + sum_{k=K+1..K+T} Z_k * l_k(z)

(through public points beta_1..beta_{K+T}) is evaluated at public points
alpha_1..alpha_N, giving client i its coded slice  X~_i = u(alpha_i)  of size
|X|/K.  Any T colluding clients learn nothing (the T masks make the coded
views uniform); any polynomial f of degree D applied pointwise to coded
slices can be decoded from R = D*(K+T-1)+1 evaluations since
h(z) = f(u(z), v(z)) has degree <= D*(K+T-1).

Because alphas/betas are public static ints, encoding and decoding are
mul-by-public-constant + add: *local* (communication-free) MPC ops -- this is
exactly why COPML beats the BGW/BH08 baselines (paper Table I).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import field


def recovery_threshold(r: int, k: int, t: int) -> int:
    """Minimum #evaluations to decode: (2r+1)(K+T-1)+1 (deg f = 2r+1)."""
    return (2 * r + 1) * (k + t - 1) + 1


def default_points(n: int, k: int, t: int) -> tuple:
    """Disjoint public evaluation points: betas = 1..K+T, alphas = K+T+1..K+T+N."""
    betas = tuple(range(1, k + t + 1))
    alphas = tuple(range(k + t + 1, k + t + 1 + n))
    return alphas, betas


def encode_matrix(alphas: Sequence[int], betas: Sequence[int]) -> np.ndarray:
    """(N, K+T) public coefficient matrix  E[i, k] = l_k(alpha_i)."""
    return field.host_lagrange_coeffs(betas, alphas)


def decode_matrix(alphas_subset: Sequence[int], betas_targets: Sequence[int]) -> np.ndarray:
    """(K, R) public matrix  D[k, j] = prod_{l != j} (beta_k - a_l)/(a_j - a_l)."""
    return field.host_lagrange_coeffs(alphas_subset, betas_targets)


def lcc_encode(blocks, mask_blocks, alphas: Sequence[int], betas: Sequence[int]):
    """Encode (K, B, D) data blocks + (T, B, D) masks -> (N, B, D) coded slices.

    Works equally on secret *shares* of the blocks (encoding is linear, so
    encoding the shares yields shares of the encodings -- Section III).
    """
    stacked = jnp.concatenate([blocks, mask_blocks], axis=0)  # (K+T, B, D)
    kt = stacked.shape[0]
    flat = stacked.reshape(kt, -1)
    e = jnp.asarray(encode_matrix(alphas, betas))  # (N, K+T)
    coded = field.matmul(e, flat)
    return coded.reshape((e.shape[0],) + stacked.shape[1:])


def lcc_decode(evals, subset_alphas: Sequence[int], betas: Sequence[int], k: int):
    """Decode h(beta_1..beta_K) from R evaluations h(alpha_j), j in subset.

    evals: (R, ...) field array of f(X~_j, w~_j) results (or shares thereof).
    Returns (K, ...) decoded per-block values f(X_k, w).
    """
    r = evals.shape[0]
    flat = evals.reshape(r, -1)
    d = jnp.asarray(decode_matrix(subset_alphas, betas[:k]))  # (K, R)
    out = field.matmul(d, flat)
    return out.reshape((k,) + evals.shape[1:])


def partition_rows(x, k: int):
    """Split rows into K equal blocks, padding with zero rows if needed.

    Returns (blocks (K, m_pad/K, d), pad_rows).
    """
    m = x.shape[0]
    per = -(-m // k)
    pad = per * k - m
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    return x.reshape((k, per) + x.shape[1:]), pad
