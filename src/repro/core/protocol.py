"""COPML: the full training protocol (paper Algorithm 1) over N virtual clients.

One process simulates all N clients; every share array carries the client
axis first.  Each step below is annotated with its MPC character
(LOCAL = no communication; EXCHANGE = point-to-point shares; OPEN = broadcast
+ reconstruct), which cost_model.py prices for the Fig-3/Table-I benchmarks,
and which launch/copml_dist.py maps onto mesh collectives.

The model-specific slice (gradient polynomial, target embedding, model
shape, update constants) comes from a core/objectives.SecureObjective:
the phases are shape-polymorphic over the objective's trailing model dims
(a (d,) vector for binary logreg / linreg, a (d, C) matrix for C-class
one-vs-rest trained on ONE dataset encoding).

Fixed-point scale plumbing (the part the paper leaves implicit, Appendix A):

  X quantized at 2^lx, w at 2^lw  =>  z = Xw at lz = lx+lw.
  ghat coefficients quantized so ghat(z) comes out at lg = lz + cb
  (cb = coefficient precision bits).
  coded gradient  f = X~^T ghat(X~ w~)  at s_grad = lx + lg.
  update: multiply by public  q_eta ~= (eta/m) * 2^e, then TruncPr by
  2^{k1}, k1 = s_grad + e - lw, returning to scale lw.

All intermediate *true* values must stay within (-2^{mag_bits} - 1, ...)
* 2^{scale} < p/2; auto_scales() solves the bit budget and asserts it.
"""

from __future__ import annotations

import dataclasses
import math
import os
import warnings
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from . import (field, lagrange, meshutil, mpc, objectives, quantize, shamir,
               truncation)
from .labels import Coded, Opened, Public, Share


@dataclasses.dataclass(frozen=True)
class CopmlConfig:
    n_clients: int
    k: int                   # parallelization (dataset split)
    t: int                   # privacy threshold
    r: int = 1               # sigmoid polynomial degree
    eta: float = 1.0
    # fixed-point scales (None => auto from m at setup time)
    lx: int = 2
    lw: int = 3
    cb: int = 6
    k1: int | None = None
    k2: int = 24
    mag_bits: int = 10       # headroom for |X^T(ghat-y)| true magnitude
    sigmoid_bound: float = 10.0
    mpc_mul: str = "bh08"    # "bh08" | "bgw"

    @property
    def lz(self) -> int:
        return self.lx + self.lw

    @property
    def lg(self) -> int:
        return self.lz + self.cb

    @property
    def s_grad(self) -> int:
        return self.lx + self.lg

    @property
    def recovery_threshold(self) -> int:
        return lagrange.recovery_threshold(self.r, self.k, self.t)

    def validate(self):
        assert self.n_clients >= self.recovery_threshold, (
            f"N={self.n_clients} < recovery threshold "
            f"{self.recovery_threshold} = (2r+1)(K+T-1)+1")
        assert self.n_clients >= 2 * self.t + 1, "MPC mult needs N >= 2T+1"
        assert self.mag_bits + self.s_grad + 2 <= field.P_BITS, (
            "fixed-point budget exceeds field size")


# Corruption offset added to an adversarial client's coded gradient.  It
# must be LARGE: the decode-weighted offset passes through TruncPr's 2^{k1}
# rescale, so a small perturbation (say +1, weighted shift ~q_eta) truncates
# away invisibly and corruption would be untestable; 2^20 leaves a clearly
# visible model change whenever a corrupted contribution enters a decode.
ADV_OFFSET = 1 << 20


def case1_params(n: int, r: int = 1) -> tuple:
    """Paper Case 1 (max parallelization): K = floor((N-1)/(2r+1)), T = 1."""
    return max(1, (n - 1) // (2 * r + 1)), 1


def case2_params(n: int, r: int = 1) -> tuple:
    """Paper Case 2 (equal split between parallelization and privacy).

    Stated in the paper for r=1 as T = floor((N-3)/6),
    K = floor((N+2)/3) - T.  The general-r form keeps the same structure:
    K+T-1 = floor((N-1)/(2r+1)) (the largest budget the recovery threshold
    (2r+1)(K+T-1)+1 <= N allows, since floor((N+2r)/(2r+1)) equals
    floor((N-1)/(2r+1)) + 1) with T taking roughly half of it; at r=1 it
    reduces exactly to the published formula.  Raises ValueError when no
    valid equal split exists (N too small for this r).
    """
    if r < 1:
        raise ValueError(f"polynomial degree r must be >= 1, got {r}")
    deg = 2 * r + 1
    t = max(1, (n - 3) // (2 * deg))
    k = max(1, (n + 2 * r) // deg - t)
    if deg * (k + t - 1) + 1 > n:
        raise ValueError(
            f"case 2 has no valid (K, T) for N={n}, r={r}: the recovery "
            f"threshold {deg * (k + t - 1) + 1} = (2r+1)(K+T-1)+1 exceeds N")
    return k, t


def derive_update_constants(cfg: CopmlConfig, m: int) -> tuple:
    """(q_eta, e, k1, k2): eta/m ~= q_eta / 2^e, q_eta a small public int.

    k2 auto-widens (up to log2 p - 1) when the derived k1 would collide with
    the configured k2 -- large m pushes the truncation deeper."""
    e = int(round(math.log2(m / cfg.eta))) + 1
    q_eta = max(1, int(round(cfg.eta / m * (1 << e))))
    k1 = cfg.k1 if cfg.k1 is not None else cfg.s_grad + e - cfg.lw
    k2 = max(cfg.k2, min(field.P_BITS - 1, k1 + 1))
    assert 0 < k1 < k2 <= field.P_BITS - 1, (k1, k2)
    return q_eta, e, k1, k2


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CopmlState:
    """Everything clients hold after the one-time setup.

    `w_shape` is the objective's model shape: (d,) for the vector
    objectives (binary logreg, linreg -- unchanged from the pre-objective
    protocol), (d, C) for the class-batched matrix model."""
    w_shares: Share              # (N,) + w_shape   Shamir shares of w^(t)
    coded_x: Coded               # (N, mk, d)       clear coded slices X~_i
    xty_shares: Share            # (N,) + w_shape   shares of X^T y (lx+lg)
    step: jnp.ndarray | int = 0


class Copml:
    """Functional COPML protocol driver (jit-friendly).

    `objective` (core/objectives.SecureObjective, default binary logistic)
    supplies everything model-specific: the quantized ghat coefficients,
    the target embedding, the model shape, and the update constants.  The
    phases below are shape-polymorphic over the objective's trailing model
    dims -- the binary path draws/reshapes exactly the pre-objective
    shapes, so it stays bit-exact to the seed goldens."""

    def __init__(self, cfg: CopmlConfig, m: int, d: int, objective=None):
        cfg.validate()
        self.cfg = cfg
        self.m, self.d = m, d
        self.obj = objectives.BINARY_LOGISTIC if objective is None \
            else objective
        self.obj.validate_cfg(cfg)
        self.out_shape = self.obj.out_shape      # () vector, (C,) matrix
        self.w_shape = (d,) + self.out_shape
        self.dw = d * self.obj.n_outputs         # flattened model width
        n, k, t = cfg.n_clients, cfg.k, cfg.t
        self.alphas, self.betas = lagrange.default_points(n, k, t)
        self.lambdas = tuple(range(k + t + 1 + n, k + t + 1 + 2 * n))
        self.q_eta, self.e, self.k1, self.k2 = self.obj.update_constants(
            cfg, m)
        # field coefficients of ghat at output scale lg given input scale lz
        self.poly_coeffs = self.obj.field_coeffs(cfg)
        self._mul = mpc.mul_bh08 if cfg.mpc_mul == "bh08" else mpc.mul_bgw
        # fused-megakernel gate, snapshotted per instance (api.fit builds a
        # fresh Copml, so tests flipping the env var always take effect):
        #   "0"      -- phase-siloed reference path
        #   "1"      -- fused one-dispatch step (ops.fused_step; Pallas if
        #               REPRO_USE_PALLAS, else the fused jnp composition)
        #   "kernel" -- force the Pallas megakernel regardless of USE_PALLAS
        self.fused_mode = os.environ.get("REPRO_FUSED_STEP", "1")

    # ------------------------------------------------------------------ setup

    def setup(self, key, client_xs: Sequence, client_ys: Sequence) -> CopmlState:
        """Phases 1-2 (one-time): quantize, secret-share, LCC-encode, X^T y.

        client_xs[j]: (m_j, d) float arrays; client_ys[j]: (m_j,) in {0,1}.

        Fully batched: clients' rows are stacked once and every phase is one
        vectorized field op -- no per-client Python loop.  Sharing the
        stacked rows in a single shamir.share call is distribution-identical
        to per-client sharing (the masking polynomial draws independent
        randomness per element either way) and collapses N share matmuls
        into one.  It also gives X and y sharing independent keys (the old
        loop reused keys[j] for both, correlating their masks).
        """
        cfg, n = self.cfg, self.cfg.n_clients
        keys = jax.random.split(key, 6)

        # Phase 1 (LOCAL): quantize into F_p -- one call over all rows.
        # The objective owns the target embedding (binary {0,1} passes
        # through; multiclass one-hots integer labels into (m, C)).
        xq = quantize.quantize(
            jnp.concatenate([jnp.asarray(x) for x in client_xs], axis=0),
            cfg.lx)                                           # (m, d)
        targets = self.obj.prepare_targets(
            np.concatenate([np.asarray(y) for y in client_ys], axis=0))
        yq = quantize.quantize(jnp.asarray(targets, jnp.float32), cfg.lg)
        # (m,) + out_shape

        # Phase 2a (EXCHANGE): Shamir-share every client's data (batched)
        x_shares = shamir.share(keys[0], xq, cfg.t, n, self.lambdas)
        y_shares = shamir.share(keys[1], yq, cfg.t, n, self.lambdas)
        # (N, m, d) / (N, m) + out_shape

        # Phase 2b (LOCAL on shares): partition rows into K blocks
        blocks, self.pad = jax.vmap(
            lambda s: lagrange.partition_rows(s, cfg.k)[0])(x_shares), 0
        # blocks: (N, K, mk, d)

        # shared random masks Z_{K+1..K+T} (offline randomness, fn. 3)
        z = field.random_field(keys[2], (cfg.t, blocks.shape[2], self.d))
        z_shares = shamir.share(keys[3], z, cfg.t, n, self.lambdas)
        # (N, T, mk, d)

        # Phase 2c (LOCAL): LCC-encode the shares; (EXCHANGE): reconstruct
        # each client's coded slice from T+1 shares (fn. 4: subgrouping)
        enc = jax.vmap(lambda b, zz: lagrange.lcc_encode(
            b, zz, self.alphas, self.betas))(blocks, z_shares)
        # enc: (N_holder, N_owner, mk, d); reconstruct over holders
        coded_x = shamir.reconstruct(enc, cfg.t, self.lambdas)  # (N, mk, d)

        # Phase 2d: X^T y via one secure matmul (degree reduction included);
        # a matrix objective contracts against all C target columns at once
        y_mat = y_shares if self.out_shape else y_shares[..., None]
        xty_shares = self._mul(
            keys[4],
            jnp.swapaxes(x_shares, 1, 2), y_mat,
            cfg.t, matmul=True, points=self.lambdas)     # (N, d, C')
        if not self.out_shape:
            xty_shares = xty_shares[..., 0]              # (N,) + w_shape

        # model init within MPC: w^(0) = 0 shared
        w_shares = shamir.share(
            keys[5], jnp.zeros(self.w_shape, field.FIELD_DTYPE),
            cfg.t, n, self.lambdas)
        return CopmlState(w_shares=w_shares, coded_x=coded_x,
                          xty_shares=xty_shares,
                          step=jnp.asarray(0, jnp.int32))

    # ------------------------------------------------------- one GD iteration

    def encode_model(self, key, w_shares: Share) -> Coded:
        """Phase 2 per-iteration: Lagrange-encode w from its shares.

        LOCAL on shares + EXCHANGE to reconstruct w~_j at client j.
        v(beta_k) = w for all k in [K]; T random vectors v_k pad the tail.
        """
        cfg, n = self.cfg, self.cfg.n_clients
        kv, ks = jax.random.split(key)
        # distinct keys: drawing v and its sharing polynomial from the same
        # key makes the sharing coefficients EQUAL v (same threefry stream),
        # letting any single share reveal the mask
        v = field.random_field(kv, (cfg.t,) + self.w_shape)
        v_shares = shamir.share(ks, v, cfg.t, n, self.lambdas)  # (N,T)+w_shape
        # LCC encoding is elementwise-linear: flatten the trailing model
        # dims so vector and matrix models share one encode path (dw = d
        # for the vector objectives -- these reshapes are no-ops there)
        w_flat = w_shares.reshape(n, self.dw)
        v_flat = v_shares.reshape(n, cfg.t, self.dw)
        blocks = jnp.broadcast_to(
            w_flat[:, None], (n, cfg.k, self.dw))                # same w in K slots
        enc = jax.vmap(lambda b, vv: lagrange.lcc_encode(
            b[:, None, :], vv[:, None, :], self.alphas, self.betas
        )[:, 0, :])(blocks, v_flat)                              # (N_holder,N_owner,dw)
        # keep enc holder-sharded: otherwise GSPMD all-gathers every
        # holder's (K+T, d) limb stack (~1 GiB/step at N=256, the dominant
        # collective of the baseline -- EXPERIMENTS.md Perf, COPML iter 2);
        # reconstruct from ALL N shares so the contraction reduce-scatters.
        enc = meshutil.maybe_constrain(enc, meshutil.CLIENTS)
        out = shamir.reconstruct(enc, cfg.t, self.lambdas, subset="all")
        return meshutil.maybe_constrain(out, meshutil.CLIENTS)   # (N, d)

    def local_gradient(self, coded_x: Coded, coded_w: Coded) -> Coded:
        """Phase 3 (LOCAL, the hot loop): f(X~_i, w~_i) = X~_i^T ghat(X~_i w~_i).

        Pure field compute on *clear coded* data.  All N clients run in ONE
        batched call: a single (N, m/bm)-grid Pallas launch on TPU,
        limb-packed batched GEMMs on the jnp reference path -- not N
        per-client dispatches via vmap.  A matrix objective's (N, dw) flat
        coded model reshapes to (N, d, C) and the matvec pair becomes a
        class-batched GEMM pair (kernels/ops.coded_gradient_matrix): one
        encoding drives all C one-vs-rest columns.

        `coded_x` may carry fewer than N leading rows (the sharded engine
        passes each shard's local clients).
        """
        from ..kernels import ops as kernel_ops
        if not self.out_shape:
            return kernel_ops.coded_gradient_batched(
                coded_x, coded_w, self.poly_coeffs)              # (N, d)
        w_mat = coded_w.reshape(coded_w.shape[0], self.d,
                                self.obj.n_outputs)
        return kernel_ops.coded_gradient_matrix(
            coded_x, w_mat, self.poly_coeffs)                    # (N, d, C)

    def decode_and_update(self, key, state: CopmlState, f_values: Coded,
                          subset: Sequence[int] | None = None, *,
                          subset_idx=None, dvec=None) -> CopmlState:
        """Phase 4: share f, decode on shares, secure model update.

        The decode subset comes in one of two forms: a static `subset`
        tuple (host constant, the pre-fault-plan path), or traced
        `subset_idx` (R,) gather indices with the matching `dvec` (R,)
        decode row -- the per-step form the fault-injection engines thread
        through their scans (one compiled program decodes from a different
        client subset every iteration)."""
        cfg, n = self.cfg, self.cfg.n_clients
        kf, kt = jax.random.split(key)
        rthr = cfg.recovery_threshold
        if subset_idx is None:
            if subset is None:
                subset = tuple(range(rthr))
            subset = tuple(subset)[:rthr]
            subset_idx = jnp.asarray(subset)
            dvec = jnp.asarray(self._decode_vec(subset))         # (R,)
        else:
            assert dvec is not None, "subset_idx needs its decode row dvec"

        # EXCHANGE: each client shares its local result
        f_shares = shamir.share_batch(kf, f_values, cfg.t, n,
                                      self.lambdas)  # (N_owner, N_holder, d)

        # EXCHANGE: transpose owner<->holder (all-to-all on the mesh), then
        # decode LOCALLY per holder.  Decoding before the transpose makes
        # GSPMD all-reduce a (K, N, d) tensor -- ~K x more wire bytes than
        # the (N, d) exchange the protocol actually needs (EXPERIMENTS.md
        # section Perf, COPML cell, iteration 1).
        per_holder = meshutil.maybe_constrain(
            jnp.swapaxes(f_shares, 0, 1), meshutil.CLIENTS)
        # (N_holder, N_owner) + w_shape; each holder decodes from its R
        # rows.  sum over K commutes with the decode matmul: fold it into
        # ONE matvec row  (sum_k D[k, :]) @ evals  -- K x less local work.
        # Trailing model dims flatten into the element axis (no-op for
        # vector objectives).
        evals = per_holder[:, subset_idx]                  # (N_h, R)+w_shape
        evals = evals.reshape(n, evals.shape[1], self.dw)
        xtg_shares = jax.vmap(
            lambda e: field.matmul(dvec[None], e)[0])(evals)
        xtg_shares = xtg_shares.reshape((n,) + self.w_shape)

        # LOCAL: gradient shares; then secure update with TruncPr
        grad_shares = field.sub(xtg_shares, state.xty_shares)
        scaled = field.mul_scalar(grad_shares, self.q_eta)
        delta_shares = truncation.trunc_pr(
            kt, scaled, self.k1, self.k2, cfg.t, self.lambdas)   # scale lw
        new_w = field.sub(state.w_shares, delta_shares)
        return dataclasses.replace(state, w_shares=new_w, step=state.step + 1)

    def _decode_vec(self, subset) -> Public:
        """Host-side (R,) decode row: sum_k D[k, :] over the K decode-matrix
        rows, mod p.  Shared by the single-device and sharded engines so both
        trace the exact same public constant."""
        sub_alphas = [self.alphas[i] for i in subset]
        dmat = lagrange.decode_matrix(
            sub_alphas, self.betas[: self.cfg.k]).astype(np.int64)  # (K, R)
        return (dmat.sum(axis=0) % field.P).astype(np.int32)

    def _fused_iteration(self, key, state: CopmlState, coded_w: Coded,
                         subset=None, *, subset_idx=None, dvec=None,
                         adv=None) -> CopmlState:
        """Phases 3+4 as ONE dispatch (kernels/ops.fused_step).

        Bit-exact with local_gradient + decode_and_update because every
        operand handed to the kernel consumes the SAME randomness stream:

        * `mix` is shamir.share(kf, ZEROS) -- identical masking coefficients
          to decode_and_update's share_batch(kf, f) (the coefficient draw
          depends only on key and shape), so share(h, o) = mix(h, o) + f(o)
          and the holder-h decode splits into the value-independent
          base[h] = dfull @ mix[h] (computed here) plus the holder-
          independent dfull @ f_adj (computed in the kernel epilogue).
        * TruncPr's r/[r]/[r0] come from truncation.trunc_pr_randomness
          with the same kt split arity and draw shapes as trunc_pr_core.

        The decode subset enters as the zero-scattered (N,) row `dfull`
        (excluded clients get weight 0), which works for both the static
        tuple form and the fault engines' traced (subset_idx, dvec) form.
        """
        from ..kernels import ops as kernel_ops
        cfg, n = self.cfg, self.cfg.n_clients
        kf, kt = jax.random.split(key)
        rthr = cfg.recovery_threshold
        if subset_idx is None:
            if subset is None:
                subset = tuple(range(rthr))
            subset = tuple(subset)[:rthr]
            dfull_np = np.zeros(n, np.int32)
            dfull_np[list(subset)] = self._decode_vec(subset)
            dfull = jnp.asarray(dfull_np)
        else:
            assert dvec is not None, "subset_idx needs its decode row dvec"
            dfull = jnp.zeros((n,), jnp.int32).at[subset_idx].set(dvec)

        c = self.obj.n_outputs
        mix = shamir.share(
            kf, jnp.zeros((n,) + self.w_shape, field.FIELD_DTYPE),
            cfg.t, n, self.lambdas)                    # (N_h, N_o) + w_shape
        base = jax.vmap(lambda mh: field.matmul(
            dfull[None], mh.reshape(n, self.dw))[0])(mix)       # (N_h, dw)

        r_sh, r0_sh = truncation.trunc_pr_randomness(
            kt, self.w_shape, self.k1, self.k2,
            lambda k, s: shamir.share(k, s, cfg.t, n, self.lambdas))
        bias = 1 << (self.k2 - 1)
        radd = field.add(r_sh, jnp.full_like(r_sh, bias))

        # reconstruct's default open subset: first T+1 holders, zero-padded
        rvec_np = np.zeros(n, np.int32)
        rvec_np[: cfg.t + 1] = shamir.recon_weights(
            self.lambdas, tuple(range(cfg.t + 1))).astype(np.int32)
        rvec = jnp.asarray(rvec_np)

        adv_off = jnp.zeros((n,), jnp.int32) if adv is None else \
            jnp.where(adv, jnp.asarray(ADV_OFFSET, jnp.int32), 0)

        mat = (n, self.d, c)
        _, new_w = kernel_ops.fused_step(
            state.coded_x,
            coded_w.reshape(mat),
            self.poly_coeffs, adv_off, dfull, rvec,
            base.reshape(mat),
            state.xty_shares.reshape(mat),
            state.w_shares.reshape(mat),
            radd.reshape(mat),
            r0_sh.reshape(mat),
            q_eta=self.q_eta, inv2k1=field.host_inv(1 << self.k1),
            k1=self.k1, force_pallas=self.fused_mode == "kernel")
        new_w = new_w.reshape((n,) + self.w_shape)
        return dataclasses.replace(state, w_shares=new_w,
                                   step=state.step + 1)

    def iteration(self, key, state: CopmlState,
                  subset: Sequence[int] | None = None, *,
                  subset_idx=None, dvec=None, adv=None) -> CopmlState:
        k1_, k2_ = jax.random.split(key)
        coded_w = self.encode_model(k1_, state.w_shares)
        if self.fused_mode != "0":
            return self._fused_iteration(k2_, state, coded_w, subset,
                                         subset_idx=subset_idx, dvec=dvec,
                                         adv=adv)
        f_values = self.local_gradient(state.coded_x, coded_w)
        if adv is not None:
            # adversarial clients contribute a CORRUPTED coded gradient --
            # any decode including one is visibly wrong (ADV_OFFSET); the
            # fault plan keeps them out of subset_idx, and the
            # bit-exactness tests prove the exclusion is real
            adv_b = adv.reshape((adv.shape[0],) + (1,) * len(self.w_shape))
            f_values = jnp.where(adv_b,
                                 field.add(f_values, jnp.asarray(
                                     ADV_OFFSET, f_values.dtype)), f_values)
        return self.decode_and_update(k2_, state, f_values, subset,
                                      subset_idx=subset_idx, dvec=dvec)

    def _jitted_step(self, subset):
        """Per-instance cache: a fresh jax.jit(partial(...)) every call
        would retrace/recompile the step on each train_eager invocation."""
        cache = self.__dict__.setdefault("_step_cache", {})
        if subset not in cache:
            cache[subset] = jax.jit(partial(self.iteration, subset=subset))
        return cache[subset]

    def _jitted_fault_step(self, with_adv: bool):
        """One jitted step with the decode subset as TRACED arrays: the
        eager fault engine swaps the subset every iteration without a
        recompile per distinct subset (a long churn schedule would
        otherwise mean a compile per step)."""
        cache = self.__dict__.setdefault("_fault_step_cache", {})
        if with_adv not in cache:
            if with_adv:
                fn = lambda key, st, idx, dv, adv: self.iteration(  # noqa: E731
                    key, st, subset_idx=idx, dvec=dv, adv=adv)
            else:
                fn = lambda key, st, idx, dv: self.iteration(  # noqa: E731
                    key, st, subset_idx=idx, dvec=dv)
            cache[with_adv] = jax.jit(fn)
        return cache[with_adv]

    # ------------------------------------------------------ fault schedules

    def plan_constants(self, step_subsets) -> tuple:
        """Host-side compilation of a fault plan's per-step decode subsets
        into the (iters, R) gather-index and decode-row arrays the engines
        consume (exact-integer Lagrange rows, one per distinct subset)."""
        return shamir.step_subset_arrays(
            step_subsets, self.cfg.recovery_threshold, self._decode_vec)

    def _fault_xs(self, step_subsets, adversaries, iters: int, subset=None):
        """(idx, dvec, adv-or-None) scan inputs for a faulty run, or None."""
        if step_subsets is None:
            assert adversaries is None, "adversaries need step_subsets"
            return None
        if subset is not None:
            raise ValueError("subset and step_subsets are mutually "
                             "exclusive: the plan chooses each step's "
                             "decode subset")
        assert len(step_subsets) == iters, (len(step_subsets), iters)
        idx, dvs = self.plan_constants(step_subsets)
        adv = None
        if adversaries is not None and np.asarray(adversaries).any():
            adv = np.asarray(adversaries, bool)
            assert adv.shape == (iters, self.cfg.n_clients), adv.shape
            adv = jnp.asarray(adv)
        return idx, dvs, adv

    # ------------------------------------------------------------------ train

    def _train_jit(self, key, client_xs, client_ys, iters: int,
                   subset: Sequence[int] | None = None,
                   history: bool = False, step_subsets=None,
                   adversaries=None) -> tuple:
        """Run setup + `iters` GD iterations as ONE compiled lax.scan.

        The whole training loop is a single XLA program (one compile, one
        dispatch) instead of `iters` Python round-trips -- same per-step
        randomness (fold_in of the iteration key) and therefore bit-exact
        against the eager loop (`train_eager`).  With history=True the scan
        also stacks the opened model after every step (used by the callback
        wrapper in `train` and by convergence diagnostics); opening inside
        the scan is trace-time work, not an extra communication round.

        step_subsets/adversaries (a fault plan's per-step decode subsets and
        (iters, N) corruption mask) ride through the scan as stacked array
        inputs, so even a fully churned run stays ONE compiled dispatch.

        Returns (state, w) or (state, w, history (iters, d)).
        """
        ks, ki = jax.random.split(key)
        state = self.setup(ks, client_xs, client_ys)
        subset = None if subset is None else tuple(subset)
        faults = self._fault_xs(step_subsets, adversaries, int(iters),
                                subset)
        state, hist = _scan_iterations(self, ki, state, int(iters), subset,
                                       bool(history), faults)
        w = self.open_model(state)
        return (state, w, hist) if history else (state, w)

    def _train_eager(self, key, client_xs, client_ys, iters: int,
                     subset: Sequence[int] | None = None,
                     callback=None, step_subsets=None,
                     adversaries=None) -> tuple:
        """Reference trainer: Python loop, one jitted iteration per step.

        Kept as the ground truth the scan engine is verified against
        (tests/test_protocol.py) and for step-through debugging.  A fault
        plan's per-step subsets are swapped in every iteration (dynamic
        gather indices -- one compile covers the whole schedule).
        """
        ks, ki = jax.random.split(key)
        state = self.setup(ks, client_xs, client_ys)
        faults = self._fault_xs(step_subsets, adversaries, iters, subset)
        if faults is None:
            step = self._jitted_step(
                None if subset is None else tuple(subset))
            args = lambda t: ()                                  # noqa: E731
        else:
            idx, dvs, adv = faults
            step = self._jitted_fault_step(adv is not None)
            args = lambda t: ((idx[t], dvs[t], adv[t])           # noqa: E731
                              if adv is not None else (idx[t], dvs[t]))
        for t in range(iters):
            state = step(jax.random.fold_in(ki, t), state, *args(t))
            if callback is not None:
                callback(t, self.open_model(state))
        return state, self.open_model(state)

    def train(self, key, client_xs, client_ys, iters: int,
              subset: Sequence[int] | None = None,
              callback=None) -> tuple:
        """Public API: scan-compiled training; callback replayed post-hoc.

        The per-step model history comes out of the single compiled scan, so
        callbacks no longer force a host round-trip every iteration.
        """
        if callback is None:
            return self._train_jit(key, client_xs, client_ys, iters,
                                   subset=subset)
        state, w, hist = self._train_jit(key, client_xs, client_ys, iters,
                                         subset=subset, history=True)
        for t in range(iters):
            callback(t, hist[t])
        return state, w

    # -------------------------------------------- deprecated engine methods
    #
    # The train_* method zoo is superseded by the repro.api facade:
    # api.fit(workload, "copml", engine) with engine in
    # {"eager", "jit", "sharded"}.  The shims below delegate through the
    # api engine dispatcher (run_copml_engine) -- the exact code path the
    # facade runs -- so shim-vs-facade parity is structural and
    # regression-tested (tests/test_api.py).

    def _deprecated(self, engine_label: str):
        warnings.warn(
            f"Copml.train_{engine_label} is deprecated; use "
            f"repro.api.fit(workload, 'copml', engine='{engine_label}') "
            f"(see docs/API.md)", DeprecationWarning, stacklevel=3)
        from ..api.protocols import run_copml_engine
        return run_copml_engine

    def train_jit(self, key, client_xs, client_ys, iters: int,
                  subset: Sequence[int] | None = None,
                  history: bool = False) -> tuple:
        """Deprecated shim for the scan engine (api engine='jit')."""
        run = self._deprecated("jit")
        state, w, hist = run(self, "jit", key, client_xs, client_ys,
                             int(iters), subset=subset, history=history)
        return (state, w, hist) if history else (state, w)

    def train_eager(self, key, client_xs, client_ys, iters: int,
                    subset: Sequence[int] | None = None,
                    callback=None) -> tuple:
        """Deprecated shim for the eager engine (api engine='eager')."""
        run = self._deprecated("eager")
        state, w, _ = run(self, "eager", key, client_xs, client_ys,
                          int(iters), subset=subset, callback=callback)
        return state, w

    def train_sharded(self, key, client_xs, client_ys, iters: int,
                      mesh=None, subset: Sequence[int] | None = None,
                      history: bool = False) -> tuple:
        """Deprecated shim for the mesh engine (api engine='sharded')."""
        from ..api.engine import EngineSpec
        run = self._deprecated("sharded")
        spec = EngineSpec("sharded", mesh=mesh)
        state, w, hist = run(self, spec, key, client_xs, client_ys,
                             int(iters), subset=subset, history=history)
        return (state, w, hist) if history else (state, w)

    def open_model(self, state: CopmlState) -> Opened:
        """Reconstruct and dequantize the model (only done at the end /
        for evaluation; during training clients hold only shares)."""
        w_field = mpc.open_shares(state.w_shares, self.cfg.t, self.lambdas)
        return quantize.dequantize(w_field, self.cfg.lw)

    # ----------------------------------------------------- distributed engine

    def _train_sharded(self, key, client_xs, client_ys, iters: int,
                       mesh=None, subset: Sequence[int] | None = None,
                       history: bool = False, step_subsets=None,
                       adversaries=None) -> tuple:
        """_train_jit with the client axis PHYSICALLY sharded over a mesh.

        Every share/coded array is split over a 1-D ("clients",) mesh
        (meshutil.client_mesh) with shard_map, so each device holds only its
        clients' state, and each protocol step lowers to the collective its
        MPC character implies:

          LOCAL     Phase-3 coded gradients, share-level add/mul-by-public
                    -> per-shard compute, zero communication
          EXCHANGE  share_batch's owner->holder share distribution
                    -> all_to_all; model-encoding reconstruct
                    -> mod-p reduce-scatter (psum_scatter_mod)
          OPEN      TruncPr's masked opening, per-step model opening
                    -> all_gather + replicated decode

        Bit-exact against train_jit: the per-step key schedule is identical,
        every random draw is replicated (same key, same shape on all shards
        -- equivalent to the paper's offline dealer, fn. 3), and the only
        cross-shard contractions are mod-p linear reductions whose shard
        partials recombine to the same canonical representative (see
        meshutil.psum_scatter_mod).  N need not divide the mesh: the client
        axis is
        zero-padded to a multiple of the shard count and padded clients are
        excluded from every reconstruction (zero Lagrange weight).

        Returns (state, w) or (state, w, history) exactly like train_jit,
        with state.w_shares materialized back to the un-padded (N, d) view.
        """
        mesh = meshutil.client_mesh() if mesh is None else mesh
        assert tuple(mesh.axis_names) == (meshutil.CLIENT_AXIS,), (
            f"train_sharded needs a 1-D ('{meshutil.CLIENT_AXIS}',) mesh, "
            f"got {mesh.axis_names}")
        n = self.cfg.n_clients
        ks, ki = jax.random.split(key)
        state = self.setup(ks, client_xs, client_ys)    # one-time, replicated
        subset = None if subset is None else tuple(subset)
        faults = self._fault_xs(step_subsets, adversaries, int(iters),
                                subset)
        fault_kind = None if faults is None else (
            "plan_adv" if faults[2] is not None else "plan")
        fn, n_pad = self._sharded_scan(mesh, int(iters), subset,
                                       bool(history), fault_kind)
        fault_args = ()
        if faults is not None:
            idx, dvs, adv = faults
            fault_args = (idx, dvs)
            if adv is not None:
                # replicated (iters, n_pad) mask; padded clients honest
                adv_pad = np.zeros((int(iters), n_pad), bool)
                adv_pad[:, :n] = np.asarray(adv)
                fault_args += (jnp.asarray(adv_pad),)
        out = fn(_pad_clients(state.w_shares, n_pad),
                 _pad_clients(state.coded_x, n_pad),
                 _pad_clients(state.xty_shares, n_pad), ki, *fault_args)
        w_pad, hist = out if history else (out, None)
        state = dataclasses.replace(
            state, w_shares=w_pad[:n],
            step=state.step + jnp.asarray(iters, jnp.int32))
        w = self.open_model(state)
        return (state, w, hist) if history else (state, w)

    def sharded_step(self, mesh, subset: Sequence[int] | None = None):
        """One sharded GD iteration as a jit-able fn(w, coded_x, xty, key)
        over PADDED (n_pad, ...) client-sharded arrays; returns (fn, n_pad).
        Used by launch/copml_dist.dryrun_cell to compile the real collective
        program and by the distributed benchmark stage."""
        subset = None if subset is None else tuple(subset)
        return self._sharded_scan(mesh, 1, subset, False)

    def _sharded_scan(self, mesh, iters: int, subset, history: bool,
                      fault_kind: str | None = None):
        """Build (and cache per instance) the jitted shard_map scan.

        fault_kind: None (static subset), "plan" (per-step (iters, R)
        decode idx/row arrays scanned over, replicated), or "plan_adv"
        (additionally an (iters, n_pad) corruption mask)."""
        cache = self.__dict__.setdefault("_sharded_cache", {})
        # compute/collective overlap: produce the EXCHANGE collectives'
        # operands per destination shard and stream them around a ppermute
        # ring (meshutil.ring_*) instead of blocking on the monolithic GEMM
        # before the first byte moves.  Bit-exact either way (see the ring
        # helpers); default on, REPRO_SHARDED_OVERLAP=0 restores the
        # monolithic collectives.  Part of the cache key: the two settings
        # compile different programs.
        overlap = os.environ.get("REPRO_SHARDED_OVERLAP", "1") != "0"
        ckey = (mesh, iters, subset, history, fault_kind, overlap)
        if ckey in cache:
            return cache[ckey]

        cfg, n = self.cfg, self.cfg.n_clients
        dw, w_shape = self.dw, self.w_shape
        assert cfg.t >= 1, "sharded engine assumes T >= 1 (as all paper cases)"
        ndev = mesh.shape[meshutil.CLIENT_AXIS]
        n_loc = -(-n // ndev)
        n_pad = n_loc * ndev
        t_, kk = cfg.t, cfg.k
        axis = meshutil.CLIENT_AXIS

        # public per-client constants, zero-padded so padded clients carry
        # zero Lagrange weight and a zero sharing polynomial
        pmat = np.zeros((n_pad, t_), np.int32)
        pmat[:n] = shamir._power_matrix(tuple(self.lambdas), t_)
        wall = np.zeros((n_pad,), np.int32)
        wall[:n] = shamir._recon_matrix(tuple(self.lambdas))[0]
        sub = tuple(range(cfg.recovery_threshold)) if subset is None \
            else tuple(subset)[: cfg.recovery_threshold]
        dvec = jnp.asarray(self._decode_vec(sub))                # (R,)
        sub_arr = jnp.asarray(sub)

        def share_rows(keyc, secret, pmat_loc):
            """This shard's holder rows of shamir.share(keyc, secret, t, n):
            the coefficient draw is replicated (same key on every shard --
            the offline dealer), only the public power-matrix rows are
            shard-local, so per-row values match the global share bits."""
            coeffs = field.random_field(keyc, (t_,) + secret.shape)
            mix = field.matmul(pmat_loc, coeffs.reshape(t_, -1))
            return field.add(
                mix.reshape((pmat_loc.shape[0],) + secret.shape), secret[None])

        def encode_model(k1_, w_loc, pmat_loc, wall_loc):
            """Phase-2 per-iteration model encoding, holder-sharded.

            Randomness shapes mirror the unsharded engine exactly ((T,) +
            w_shape draws, replicated dealer), so the engines stay
            bit-exact for every objective; the trailing model dims flatten
            to dw for the encode matmuls as in Copml.encode_model."""
            kv, ks_ = jax.random.split(k1_)
            v = field.random_field(kv, (t_,) + w_shape)
            v_sh = share_rows(ks_, v, pmat_loc)            # (n_loc,T)+w_shape
            n_loc_ = w_loc.shape[0]
            w_flat = w_loc.reshape(n_loc_, dw)
            v_flat = v_sh.reshape(n_loc_, t_, dw)
            blocks = jnp.broadcast_to(w_flat[:, None], (n_loc_, kk, dw))
            enc = jax.vmap(lambda b, vv: lagrange.lcc_encode(
                b[:, None, :], vv[:, None, :], self.alphas, self.betas
            )[:, 0, :])(blocks, v_flat)                          # (n_loc,N,dw)
            # EXCHANGE: reconstruct from ALL holders -- local weighted
            # partial, then a mod-p reduce-scatter hands each shard its own
            # clients' coded model rows
            if overlap and ndev <= meshutil.NARROW_SHARDS:
                if n_pad > n:
                    enc = jnp.concatenate(
                        [enc, jnp.zeros((enc.shape[0], n_pad - n, dw),
                                        jnp.int32)], axis=1)

                def seg(j):
                    # dest shard j's rows of the weighted partial, computed
                    # just before hop j so the GEMM rides the transfer
                    sl = jax.lax.dynamic_slice_in_dim(
                        enc, j * n_loc, n_loc, axis=1)
                    return field.matmul(
                        wall_loc[None, :],
                        sl.reshape(sl.shape[0], -1)).reshape(n_loc, dw)

                return meshutil.ring_reduce_scatter_mod(seg, axis, ndev)
            part = field.matmul(wall_loc[None, :],
                                enc.reshape(enc.shape[0], -1)).reshape(n, dw)
            if n_pad > n:
                part = jnp.concatenate(
                    [part, jnp.zeros((n_pad - n, dw), jnp.int32)], axis=0)
            return meshutil.psum_scatter_mod(part, axis, ndev)   # (n_loc, dw)

        def trunc(kt, a_loc, pmat_loc):
            """TruncPr (truncation.trunc_pr_core) with shard-local share
            rows and the masked value OPENed via all_gather."""
            def open_(c_sh):
                c_full = meshutil.all_gather_clients(c_sh, axis)[:n]
                return shamir.reconstruct(c_full, t_, self.lambdas)

            return truncation.trunc_pr_core(
                kt, a_loc, self.k1, self.k2,
                share=lambda kc, s: share_rows(kc, s, pmat_loc),
                open_=open_)

        def decode_update(k2_, w_loc, xty_loc, f_loc, pmat_loc, pmat_all,
                          shard_ix, sub_t, dv_t):
            """Phase 4, owner->holder exchange as a real all_to_all.

            sub_t / dv_t: this step's decode gather indices and decode row
            (the closure constants on the static path, per-step scan slices
            on the fault-plan path)."""
            kf, kt = jax.random.split(k2_)
            # EXCHANGE: share_batch.  The sharing-polynomial draw spans ALL
            # owners (replicated dealer randomness, matching the global
            # (T, N) + w_shape draw bit-for-bit); each shard keeps its own
            # owners' columns and deals shares to every holder.  Trailing
            # model dims flatten to dw for the exchange/decode matmuls.
            coeffs = field.random_field(kf, (t_, n) + w_shape)
            coeffs = coeffs.reshape(t_, n, dw)
            if n_pad > n:
                coeffs = jnp.concatenate(
                    [coeffs, jnp.zeros((t_, n_pad - n, dw), jnp.int32)],
                    axis=1)
            cl = jax.lax.dynamic_slice_in_dim(
                coeffs, shard_ix * n_loc, n_loc, axis=1)        # (T,n_loc,dw)
            f_flat = f_loc.reshape(n_loc, dw)
            if overlap:
                def blk(j):
                    # holder rows owned by shard j, built just before the
                    # hop that carries them
                    pj = jax.lax.dynamic_slice_in_dim(
                        pmat_all, j * n_loc, n_loc, axis=0)
                    mixj = field.matmul(pj, cl.reshape(t_, -1))
                    return field.add(mixj.reshape(n_loc, n_loc, dw),
                                     f_flat[None])

                blocks = meshutil.ring_all_to_all(blk, axis, ndev)
                # (src, n_loc_holder, n_loc_own, dw) -> owner-major concat
                per_holder = jnp.moveaxis(blocks, 0, 1).reshape(
                    n_loc, n_pad, dw)
            else:
                mix = field.matmul(pmat_all, cl.reshape(t_, -1))
                mine = field.add(mix.reshape(n_pad, n_loc, dw),
                                 f_flat[None])    # (N_holder, n_loc_own, dw)
                per_holder = meshutil.all_to_all_clients(mine, axis)
            # (n_loc_holder, N_owner, dw): decode LOCALLY per holder
            evals = per_holder[:, sub_t, :]                     # (n_loc,R,dw)
            xtg = jax.vmap(
                lambda e: field.matmul(dv_t[None], e)[0])(evals)
            grad = field.sub(xtg.reshape((n_loc,) + w_shape), xty_loc)
            scaled = field.mul_scalar(grad, self.q_eta)
            delta = trunc(kt, scaled, pmat_loc)
            return field.sub(w_loc, delta)

        def open_w(w_loc):
            w_full = meshutil.all_gather_clients(w_loc, axis)[:n]
            wf = shamir.reconstruct(w_full, t_, self.lambdas)
            return quantize.dequantize(wf, cfg.lw)

        def loop(w, coded_x, xty, pmat_loc, wall_loc, key, *fxs):
            shard_ix = jax.lax.axis_index(axis)
            pmat_all = jnp.asarray(pmat)          # replicated full power mat

            def body(w_c, xs):
                tstep, fx = xs[0], xs[1:]
                kit = jax.random.fold_in(key, tstep)
                k1_, k2_ = jax.random.split(kit)
                coded_w = encode_model(k1_, w_c, pmat_loc, wall_loc)
                f_loc = self.local_gradient(coded_x, coded_w)    # LOCAL
                if fault_kind == "plan_adv":
                    sub_t, dv_t, adv_t = fx
                    adv_loc = jax.lax.dynamic_slice_in_dim(
                        adv_t, shard_ix * n_loc, n_loc)
                    adv_b = adv_loc.reshape((n_loc,) + (1,) * len(w_shape))
                    f_loc = jnp.where(adv_b,
                                      field.add(f_loc, jnp.asarray(
                                          ADV_OFFSET, f_loc.dtype)), f_loc)
                elif fault_kind == "plan":
                    sub_t, dv_t = fx
                else:
                    sub_t, dv_t = sub_arr, dvec
                w_n = decode_update(k2_, w_c, xty, f_loc, pmat_loc, pmat_all,
                                    shard_ix, sub_t, dv_t)
                return w_n, (open_w(w_n) if history else None)

            w_f, hist = jax.lax.scan(body, w, (jnp.arange(iters),) + fxs)
            return (w_f, hist) if history else w_f

        n_fx = {"plan": 2, "plan_adv": 3}.get(fault_kind, 0)
        cl = P(axis)
        out_specs = (cl, P()) if history else cl
        sm = shard_map(loop, mesh,
                       in_specs=(cl, cl, cl, cl, cl, P()) + (P(),) * n_fx,
                       out_specs=out_specs, check_rep=False)
        jfn = jax.jit(sm)
        pmat_j, wall_j = jnp.asarray(pmat), jnp.asarray(wall)

        def call(w, coded_x, xty, key, *fault_args):
            return jfn(w, coded_x, xty, pmat_j, wall_j, key, *fault_args)

        cache[ckey] = (call, n_pad)
        return cache[ckey]


def _pad_clients(arr, n_pad: int):
    """Zero-pad the leading client axis to n_pad rows (mesh divisibility)."""
    n = arr.shape[0]
    if n == n_pad:
        return arr
    pad = jnp.zeros((n_pad - n,) + arr.shape[1:], arr.dtype)
    return jnp.concatenate([arr, pad], axis=0)


@partial(jax.jit, static_argnames=("proto", "iters", "subset", "history"))
def _scan_iterations(proto: Copml, key, state: CopmlState, iters: int,
                     subset, history: bool, faults=None):
    """lax.scan over GD iterations; the whole loop is one XLA program.

    `proto` is static (hashed by identity): the scan recompiles per protocol
    instance but runs every iteration inside a single dispatch.  Per-step
    keys are fold_in(key, t) -- identical to the eager loop's schedule.

    `faults` is None or (idx (iters, R), dvec (iters, R), adv (iters, N)
    or None): a fault plan's per-step decode subsets (and corruption mask)
    scanned over alongside the step counter -- churn costs zero extra
    dispatches.
    """

    def body(st, xs):
        t, fx = xs
        if fx is None:
            st = proto.iteration(jax.random.fold_in(key, t), st, subset)
        else:
            idx_t, dv_t, adv_t = fx
            st = proto.iteration(jax.random.fold_in(key, t), st,
                                 subset_idx=idx_t, dvec=dv_t, adv=adv_t)
        return st, (proto.open_model(st) if history else None)

    return jax.lax.scan(body, state, (jnp.arange(iters), faults))
