"""Beyond-paper: COPML-coded secure gradient aggregation for the LM framework.

The paper's technique end-to-end needs a *polynomial* forward pass, so it
cannot wrap a transformer (DESIGN.md section 6).  What transfers to any
architecture is the aggregation step: per-data-shard gradients g_1..g_N are
only ever *summed* across the data axis -- a degree-1 polynomial, LCC's
sweet spot.  This module gives the trainer:

  * information-theoretic privacy of each host's gradient against any T
    colluding hosts (Shamir threshold),
  * K-fold per-host communication/compute reduction by partitioning the
    gradient vector into K chunks (each chunk aggregated by a different
    subgroup, the paper's fn.-4 subgrouping applied to aggregation --
    the Turbo-Aggregate [35] pattern the paper cites),
  * straggler tolerance: any T+1 holders of a chunk's shares suffice.

Quantization reuses App. A (quantize.py); averaging reuses the paper's
TruncPr secure truncation so the mean comes back at the model's scale.

The functions are pure and vmap/shard_map friendly; launch/train.py wires
them across the mesh 'data' axis, where shamir.share's N output rows become
an all_to_all and the share-sum a psum.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import field, quantize, shamir, truncation
from .labels import Opened, Share


@dataclasses.dataclass(frozen=True)
class SecureAggConfig:
    n_clients: int            # hosts on the data axis
    t: int = 1                # privacy threshold
    k: int = 1                # gradient-chunk parallelization
    lq: int = 16              # gradient fixed-point fractional bits
    clip: float = 8.0         # pre-quantization gradient clip (range bound)
    k2: int = 24

    def validate(self):
        assert self.n_clients >= self.t + 1
        assert self.clip * (1 << self.lq) * self.n_clients < field.P // 2, (
            "sum range exceeds field; lower lq or clip")


def flatten_grads(grads) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]
    return flat, (treedef, shapes)


def unflatten_grads(flat, meta):
    treedef, shapes = meta
    out, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def encode_local(key, grad_flat, cfg: SecureAggConfig) -> Share:
    """Client-side: clip, quantize, Shamir-share own gradient.

    Returns (N, L) shares -- row i goes to host i (all_to_all on the mesh).
    """
    cfg.validate()
    g = jnp.clip(grad_flat, -cfg.clip, cfg.clip)
    q = quantize.quantize(g, cfg.lq)
    return shamir.share(key, q, cfg.t, cfg.n_clients)


def aggregate_shares(all_shares: Share) -> Share:
    """Holder-side: sum incoming shares (LOCAL -- field add only).

    all_shares: (N_owner, L) rows received by this holder.  Returns (L,)
    share of sum_j g_j.
    """
    acc = all_shares[0]
    for j in range(1, all_shares.shape[0]):
        acc = field.add(acc, all_shares[j])
    return acc


def decode_mean(key, sum_shares: Share, cfg: SecureAggConfig,
                subset: Sequence[int] | None = None, sel=None) -> Opened:
    """Reconstruct sum from any T+1 shares, secure-truncate to the mean.

    sum_shares: (N_holder, L) shares of the sum.  Uses TruncPr with
    k1 = log2(N) so the opened value is mean = sum / N with stochastic
    rounding (unbiased, Thm-1-compatible noise).

    sel: optional (idx (T+1,), weights (T+1,)) TRACED share selection (see
    shamir.reconstruct_dyn) -- the per-step T+1-of-N holder choice of the
    fault-injection engines; `subset` stays the static-host alternative.
    """
    n = cfg.n_clients
    k1 = max(1, int(round(math.log2(n))))
    eff_n = 1 << k1                                  # exact power-of-two divisor
    # TruncPr needs the biased value within 2^k2 <= 2^25; the sum's range is
    # N * clip * 2^lq, so derive k2 from it:
    k2 = min(field.P_BITS - 1,
             int(math.ceil(math.log2(cfg.clip * (1 << cfg.lq) * n))) + 2)
    truncated = truncation.trunc_pr(key, sum_shares, k1, k2, cfg.t)
    if sel is not None:
        opened = shamir.reconstruct_dyn(truncated, sel[0], sel[1])
    else:
        opened = shamir.reconstruct(truncated, cfg.t, subset=subset)
    mean = quantize.dequantize(opened, cfg.lq) * (eff_n / n)
    return mean


def selection_arrays(cfg: SecureAggConfig, step_subsets) -> tuple:
    """Host-compile a fault plan's per-step holder subsets into the
    (iters, T+1) gather-index and Lagrange-weight arrays decode_mean's
    dynamic path consumes (weights computed once per distinct subset)."""
    points = shamir.default_eval_points(cfg.n_clients)
    return shamir.step_subset_arrays(
        step_subsets, cfg.t + 1,
        lambda sub: shamir.recon_weights(points, sub))


def secure_aggregate(key, grads_per_client, cfg: SecureAggConfig,
                     subset: Sequence[int] | None = None):
    """Reference (single-process) path: full round trip over a pytree list.

    grads_per_client: list of N gradient pytrees (same structure).
    Returns the privacy-preserving mean gradient pytree.
    """
    flats, metas = zip(*(flatten_grads(g) for g in grads_per_client))
    keys = jax.random.split(key, cfg.n_clients + 1)
    shares = jnp.stack([encode_local(keys[j], flats[j], cfg)
                        for j in range(cfg.n_clients)])   # (owner, holder, L)
    per_holder = jnp.swapaxes(shares, 0, 1)               # (holder, owner, L)
    sum_shares = jax.vmap(aggregate_shares)(per_holder)   # (holder, L)
    mean = decode_mean(keys[-1], sum_shares, cfg, subset)
    return unflatten_grads(mean, metas[0])


# --------------------------------------------- secure-agg logistic regression
#
# The paper's comparison workload trained with gradient privacy ONLY: each
# client computes its local float gradient in the clear, and the exchange
# is COPML-coded secure aggregation (the degree-1 slice of the paper's
# technique).  The model itself is public every step -- a deliberately
# weaker trust model than full COPML, priced as the "secure_agg" protocol
# of the repro.api registry.


def _padded_clients(client_xs, client_ys, objective=None):
    """Stack ragged per-client rows into (N, mmax, d) + a row mask.

    `objective` (core/objectives) owns the target embedding: targets are
    (N, mmax) + out_shape (binary/regression pass through, multi-class
    one-hots integer labels)."""
    n = len(client_xs)
    sizes = [int(np.asarray(x).shape[0]) for x in client_xs]
    mmax, d = max(sizes), int(np.asarray(client_xs[0]).shape[1])
    out_shape = () if objective is None else objective.out_shape
    xs = np.zeros((n, mmax, d), np.float32)
    ys = np.zeros((n, mmax) + out_shape, np.float32)
    mask = np.zeros((n, mmax), np.float32)
    for j, (x, y) in enumerate(zip(client_xs, client_ys)):
        xs[j, : sizes[j]] = np.asarray(x, np.float32)
        yj = np.asarray(y, np.float32) if objective is None else \
            objective.prepare_targets(np.asarray(y))
        ys[j, : sizes[j]] = yj
        mask[j, : sizes[j]] = 1.0
    return jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(mask)


def _client_mean_grads(xs, ys, mask, w, objective=None):
    """Per-client MEAN gradients over the padded rows: (N, d) for a (d,)
    vector model, (N, d, C) for a (d, C) matrix model (columnwise
    one-vs-rest).  Default objective = binary logistic (sigmoid)."""
    act = jax.nn.sigmoid if objective is None else objective.act_jnp
    if w.ndim == 1:
        z = jnp.einsum("nmd,d->nm", xs, w)
        err = (act(z) - ys) * mask
        g = jnp.einsum("nmd,nm->nd", xs, err)
        return g / jnp.sum(mask, axis=1, keepdims=True)
    z = jnp.einsum("nmd,dc->nmc", xs, w)
    err = (act(z) - ys) * mask[..., None]
    g = jnp.einsum("nmd,nmc->ndc", xs, err)
    return g / jnp.sum(mask, axis=1)[:, None, None]


def _secure_mean_step(key, g, cfg: SecureAggConfig, subset,
                      sel=None) -> Opened:
    """One aggregation round on (N, d) gradients: the same key schedule and
    field ops as secure_aggregate over [{'g': g[j]}] pytrees."""
    keys = jax.random.split(key, cfg.n_clients + 1)
    shares = jax.vmap(lambda k, gj: encode_local(k, gj, cfg))(
        keys[: cfg.n_clients], g)                        # (owner, holder, d)
    per_holder = jnp.swapaxes(shares, 0, 1)
    sum_shares = jax.vmap(aggregate_shares)(per_holder)
    return decode_mean(keys[cfg.n_clients], sum_shares, cfg, subset, sel)


def secure_logreg(key, client_xs, client_ys, cfg: SecureAggConfig,
                  eta: float, iters: int,
                  subset: Sequence[int] | None = None, callback=None,
                  step_subsets=None, objective=None):
    """Eager engine: Python loop, one secure_aggregate round per GD step.

    Each step j's local gradient is the client's mean gradient, so the
    decoded mean-of-means equals the full-batch gradient (up to split
    raggedness).  `step_subsets` (a fault plan's per-step T+1 holder
    choices) overrides `subset` with a different reconstruction subset
    every round.  `objective` (default binary logistic) picks the gradient
    and model shape: a matrix objective's (d, C) gradient is flattened for
    the aggregation round and reshaped back -- the exchange is
    shape-oblivious.  Returns the final float model, (d,) or (d, C)."""
    cfg.validate()
    xs, ys, mask = _padded_clients(client_xs, client_ys, objective)
    sel_arrays = None if step_subsets is None else \
        selection_arrays(cfg, step_subsets)
    w_shape = (xs.shape[2],) if objective is None else \
        objective.w_shape(xs.shape[2])
    w = jnp.zeros(w_shape, jnp.float32)
    for t in range(iters):
        g = _client_mean_grads(xs, ys, mask, w, objective)
        g_flat = g.reshape(cfg.n_clients, -1)
        if sel_arrays is not None:
            mean = _secure_mean_step(
                jax.random.fold_in(key, t), g_flat, cfg, None,
                (sel_arrays[0][t], sel_arrays[1][t]))
        else:
            grads = [{"g": g_flat[j]} for j in range(cfg.n_clients)]
            mean = secure_aggregate(jax.random.fold_in(key, t), grads, cfg,
                                    subset)["g"]
        w = w - eta * mean.reshape(w_shape).astype(jnp.float32)
        if callback is not None:
            callback(t, np.asarray(w))
    return np.asarray(w)


def secure_logreg_scan(key, client_xs, client_ys, cfg: SecureAggConfig,
                       eta: float, iters: int,
                       subset: Sequence[int] | None = None,
                       history: bool = True, step_subsets=None,
                       objective=None):
    """jit engine: the whole loop as one compiled lax.scan.

    Same per-step fold_in key schedule and the same share/decode field ops
    as the eager loop (the aggregation rounds are bit-identical; only the
    float gradient einsum may differ in summation order).  A fault plan's
    `step_subsets` ride through the scan as stacked (iters, T+1)
    index/weight arrays -- the churned run stays one dispatch.  Returns
    (w, history) with w the objective's model shape and history
    (iters,) + that shape, or None."""
    cfg.validate()
    xs, ys, mask = _padded_clients(client_xs, client_ys, objective)
    subset = None if subset is None else tuple(subset)
    sel = None if step_subsets is None else \
        selection_arrays(cfg, step_subsets)
    w, hist = _secure_logreg_jit(key, xs, ys, mask, cfg, float(eta),
                                 int(iters), subset, bool(history), sel,
                                 objective)
    return np.asarray(w), (None if hist is None else np.asarray(hist))


@partial(jax.jit, static_argnames=("cfg", "eta", "iters", "subset",
                                   "history", "objective"))
def _secure_logreg_jit(key, xs, ys, mask, cfg, eta, iters, subset, history,
                       sel=None, objective=None):
    w_shape = (xs.shape[2],) if objective is None else \
        objective.w_shape(xs.shape[2])

    def body(w, xs_t):
        t, sel_t = xs_t
        g = _client_mean_grads(xs, ys, mask, w, objective)
        mean = _secure_mean_step(jax.random.fold_in(key, t),
                                 g.reshape(cfg.n_clients, -1), cfg, subset,
                                 sel_t)
        w = w - eta * mean.reshape(w_shape).astype(jnp.float32)
        return w, (w if history else None)

    return jax.lax.scan(body, jnp.zeros(w_shape, jnp.float32),
                        (jnp.arange(iters), sel))
