"""Beyond-paper: COPML-coded secure gradient aggregation for the LM framework.

The paper's technique end-to-end needs a *polynomial* forward pass, so it
cannot wrap a transformer (DESIGN.md section 6).  What transfers to any
architecture is the aggregation step: per-data-shard gradients g_1..g_N are
only ever *summed* across the data axis -- a degree-1 polynomial, LCC's
sweet spot.  This module gives the trainer:

  * information-theoretic privacy of each host's gradient against any T
    colluding hosts (Shamir threshold),
  * K-fold per-host communication/compute reduction by partitioning the
    gradient vector into K chunks (each chunk aggregated by a different
    subgroup, the paper's fn.-4 subgrouping applied to aggregation --
    the Turbo-Aggregate [35] pattern the paper cites),
  * straggler tolerance: any T+1 holders of a chunk's shares suffice.

Quantization reuses App. A (quantize.py); averaging reuses the paper's
TruncPr secure truncation so the mean comes back at the model's scale.

The functions are pure and vmap/shard_map friendly; launch/train.py wires
them across the mesh 'data' axis, where shamir.share's N output rows become
an all_to_all and the share-sum a psum.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import field, quantize, shamir, truncation


@dataclasses.dataclass(frozen=True)
class SecureAggConfig:
    n_clients: int            # hosts on the data axis
    t: int = 1                # privacy threshold
    k: int = 1                # gradient-chunk parallelization
    lq: int = 16              # gradient fixed-point fractional bits
    clip: float = 8.0         # pre-quantization gradient clip (range bound)
    k2: int = 24

    def validate(self):
        assert self.n_clients >= self.t + 1
        assert self.clip * (1 << self.lq) * self.n_clients < field.P // 2, (
            "sum range exceeds field; lower lq or clip")


def flatten_grads(grads) -> tuple:
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    shapes = [(l.shape, l.dtype) for l in leaves]
    return flat, (treedef, shapes)


def unflatten_grads(flat, meta):
    treedef, shapes = meta
    out, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        out.append(flat[off:off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def encode_local(key, grad_flat, cfg: SecureAggConfig):
    """Client-side: clip, quantize, Shamir-share own gradient.

    Returns (N, L) shares -- row i goes to host i (all_to_all on the mesh).
    """
    cfg.validate()
    g = jnp.clip(grad_flat, -cfg.clip, cfg.clip)
    q = quantize.quantize(g, cfg.lq)
    return shamir.share(key, q, cfg.t, cfg.n_clients)


def aggregate_shares(all_shares):
    """Holder-side: sum incoming shares (LOCAL -- field add only).

    all_shares: (N_owner, L) rows received by this holder.  Returns (L,)
    share of sum_j g_j.
    """
    acc = all_shares[0]
    for j in range(1, all_shares.shape[0]):
        acc = field.add(acc, all_shares[j])
    return acc


def decode_mean(key, sum_shares, cfg: SecureAggConfig,
                subset: Sequence[int] | None = None):
    """Reconstruct sum from any T+1 shares, secure-truncate to the mean.

    sum_shares: (N_holder, L) shares of the sum.  Uses TruncPr with
    k1 = log2(N) so the opened value is mean = sum / N with stochastic
    rounding (unbiased, Thm-1-compatible noise).
    """
    n = cfg.n_clients
    k1 = max(1, int(round(math.log2(n))))
    eff_n = 1 << k1                                  # exact power-of-two divisor
    # TruncPr needs the biased value within 2^k2 <= 2^25; the sum's range is
    # N * clip * 2^lq, so derive k2 from it:
    k2 = min(field.P_BITS - 1,
             int(math.ceil(math.log2(cfg.clip * (1 << cfg.lq) * n))) + 2)
    truncated = truncation.trunc_pr(key, sum_shares, k1, k2, cfg.t)
    opened = shamir.reconstruct(truncated, cfg.t, subset=subset)
    mean = quantize.dequantize(opened, cfg.lq) * (eff_n / n)
    return mean


def secure_aggregate(key, grads_per_client, cfg: SecureAggConfig,
                     subset: Sequence[int] | None = None):
    """Reference (single-process) path: full round trip over a pytree list.

    grads_per_client: list of N gradient pytrees (same structure).
    Returns the privacy-preserving mean gradient pytree.
    """
    flats, metas = zip(*(flatten_grads(g) for g in grads_per_client))
    keys = jax.random.split(key, cfg.n_clients + 1)
    shares = jnp.stack([encode_local(keys[j], flats[j], cfg)
                        for j in range(cfg.n_clients)])   # (owner, holder, L)
    per_holder = jnp.swapaxes(shares, 0, 1)               # (holder, owner, L)
    sum_shares = jax.vmap(aggregate_shares)(per_holder)   # (holder, L)
    mean = decode_mean(keys[-1], sum_shares, cfg, subset)
    return unflatten_grads(mean, metas[0])
