"""Secure truncation (TruncPr, Catrina & Saxena [37]) on Shamir shares.

Given shares [a] of a fixed-point value a in (-2^{k2-1}, 2^{k2-1}) embedded in
F_p, returns shares [z] with  z = floor(a / 2^{k1}) + s,
P(s = 1) = (a mod 2^{k1}) / 2^{k1}  -- i.e. stochastic rounding of a/2^{k1}
(exactly the behavior the paper states in Section III, Phase 4).

Protocol (passively secure, statistical privacy in the k2 -> log p gap):
  offline: r uniform in [0, 2^{k2+kappa}); dealer shares [r] and [r0] where
           r0 = r mod 2^{k1}.
  online:  open c = a + 2^{k2-1} + r  (mod p); c0 = c mod 2^{k1};
           [a0] = c0 - [r0] + 2^{k1} * [b]  where b in {0,1} is the borrow
           (c0 < r0).  TruncPr folds the borrow into the stochastic rounding:
           [z] = (  [a] - [a0]  ) * inv(2^{k1})      -- mul by public const.
The borrow bit is exactly what produces the +s Bernoulli offset.

With p = 2^26 - 5 the statistical hiding gap kappa = log2(p) - k2 is small
(the paper itself reports *statistical*, not perfect, privacy for this
step); we document kappa in the returned info.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import field, shamir
from .labels import SecretRand, Share


def trunc_pr_randomness(key, shape, k1: int, k2: int, share):
    """The offline, value-INDEPENDENT half of TruncPr: draw r, deal [r], [r0].

    Extracted so the fused megakernel path (kernels/fused_step.py) can
    pre-deal the correlated randomness and hand the kernel epilogue only
    the share arrays -- consuming the key stream IDENTICALLY to
    trunc_pr_core (same split arity, same draw shapes, same share calls),
    which is what keeps the fused engines bit-exact with the reference.
    """
    kr, ks1, ks2 = jax.random.split(key, 3)
    # offline correlated randomness (crypto-service provider / PRSS, fn. 3)
    r: SecretRand = jax.random.randint(kr, shape, 0, 1 << k2,
                                       dtype=jnp.int32)
    r0 = jnp.bitwise_and(r, (1 << k1) - 1)
    r_sh = share(ks1, r.astype(field.FIELD_DTYPE))
    r0_sh = share(ks2, r0.astype(field.FIELD_DTYPE))
    return r_sh, r0_sh


def trunc_pr_core(key, a_shares: Share, k1: int, k2: int,
                  share, open_) -> Share:
    """TruncPr's arithmetic, parameterized over the share/open primitives.

    `share(key, secret)` deals Shamir shares of the offline randomness and
    `open_(c_shares)` publicly reconstructs the masked value.  The
    single-device path (trunc_pr below) passes the plain shamir ops; the
    mesh-sharded engine (protocol.Copml._sharded_scan) passes its local-row
    share and all_gather-backed open -- ONE source of truth for the bias /
    mask / borrow-fold math, so the two engines cannot drift.

    a_shares: (N_local_or_global, ...) shares.  Returns shares of
    floor(a/2^{k1}) + Bernoulli((a mod 2^{k1})/2^{k1}).
    """
    assert 0 < k1 < k2 < field.P_BITS
    shape = a_shares.shape[1:]
    r_sh, r0_sh = trunc_pr_randomness(key, shape, k1, k2, share)

    # online: open c = a + 2^{k2-1} + r  (bias makes the value positive)
    bias = 1 << (k2 - 1)
    c_sh = field.add(a_shares, field.add(r_sh, jnp.full_like(a_shares, bias)))
    c = open_(c_sh)
    c0 = jnp.bitwise_and(c, (1 << k1) - 1)

    # [a0] = c0 - [r0]  (+2^{k1} borrow, folded into the stochastic offset)
    a0_sh = field.sub(jnp.broadcast_to(c0[None], r0_sh.shape), r0_sh)
    # [z] = ([a] - [a0]) / 2^{k1}
    num = field.sub(a_shares, a0_sh)
    inv_2k1 = field.host_inv(1 << k1)
    return field.mul_scalar(num, inv_2k1)


def trunc_pr(key, a_shares: Share, k1: int, k2: int, t: int,
             points=None) -> Share:
    """Probabilistic truncation of shared fixed-point values by 2^{k1}.

    a_shares: (N, ...) Shamir shares.  Returns (N, ...) shares of
    floor(a/2^{k1}) + Bernoulli((a mod 2^{k1})/2^{k1}).
    """
    n = a_shares.shape[0]
    if points is None:
        points = shamir.default_eval_points(n)
    return trunc_pr_core(
        key, a_shares, k1, k2,
        share=lambda k, s: shamir.share(k, s, t, n, points),
        open_=lambda c_sh: shamir.reconstruct(c_sh, t, points))


def statistical_gap(k2: int) -> float:
    """kappa = log2 p - k2 bits of statistical hiding."""
    import math
    return math.log2(field.P) - k2
