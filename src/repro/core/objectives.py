"""SecureObjective: the model-specific slice of the COPML pipeline.

The protocol (quantize -> LCC-encode -> polynomial gradient -> secure
truncated update, core/protocol.py) is model-agnostic: every phase
operates on field arrays whose trailing dims are the model's.  What is
actually specific to "binary logistic regression" is exactly four things:

  1. the degree-r polynomial ghat whose quantized coefficients enter the
     coded-gradient kernel (Eq. 5: the sigmoid's least-squares fit),
  2. how the training targets embed into the field (y at scale 2^lg so
     ghat(Xw) - y is a single share-level subtraction),
  3. the model's shape -- a (d,) vector, or a (d, C) matrix whose C
     columns are trained simultaneously on one dataset encoding,
  4. the float reference used for update constants and accuracy scoring.

A SecureObjective bundles those four.  Three implementations:

  BinaryLogistic       the paper's objective; bit-exact to the pre-split
                       protocol (same coefficient quantization, same
                       (d,)-shaped randomness draws).
  LinearRegression     ghat(z) = z exactly (degree 1, zero coefficient
                       rounding error): gradient X^T(Xw - y).  Requires
                       cfg.r == 1, the lowest recovery threshold
                       3(K+T-1)+1.
  MulticlassLogistic   C one-vs-rest logistic columns as ONE (d, C) field
                       matrix: the dataset is quantized/shared/LCC-encoded
                       once and every phase carries a trailing class axis,
                       so the hot loop is a field matmul X~^T ghat(X~ W)
                       instead of C matvec dispatches, and the per-client
                       exchange grows only by the model width (the
                       CodedPrivateML encode-once/compute-many structure).

Objectives are frozen dataclasses (hashable -- api.Workload caches
protocol drivers per workload) and registered by name (`logistic`,
`linreg`, `ovr10`) for the docs lint and the CLI.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import field, sigmoid_approx
from .labels import Public


@dataclasses.dataclass(frozen=True)
class SecureObjective:
    """Base class: quantized polynomial gradient spec + float reference.

    Subclasses override the class attributes / methods below; everything
    the protocol layers consume is expressed through this interface, so a
    new model family plugs into copml / mpc_baseline / float / poly_float
    / secure_agg without touching the phase code.
    """

    name = "?"
    dataset_kind = "binary"       # data/pipeline builder: binary |
    #                               multiclass | regression
    n_outputs = 1                 # C: model columns (1 = vector model)

    # ------------------------------------------------------------- shapes

    @property
    def out_shape(self) -> tuple:
        """Trailing model/target dims: () for a vector model, (C,) for a
        class-batched matrix model."""
        return () if self.n_outputs == 1 else (self.n_outputs,)

    def w_shape(self, d: int) -> tuple:
        return (d,) + self.out_shape

    # ---------------------------------------------- polynomial gradient

    def validate_cfg(self, cfg) -> None:
        """Raise ValueError if cfg's polynomial degree cannot express this
        objective's gradient."""
        if cfg.r < 1:
            raise ValueError(f"objective {self.name!r} needs degree r >= 1")

    def float_coeffs(self, r: int, bound: float) -> tuple:
        """ghat's float coefficients c_0..c_r, lowest degree first."""
        raise NotImplementedError

    def field_coeffs(self, cfg) -> Public:
        """Field-embedded ghat coefficients on the protocol's scale ladder:
        degree-i coefficient quantized at 2^(lg - i*lz) so ghat of an
        lz-scaled argument comes out at scale lg (App. A)."""
        self.validate_cfg(cfg)
        scales = [cfg.lg - i * cfg.lz for i in range(cfg.r + 1)]
        out = []
        for c, s in zip(self.float_coeffs(cfg.r, cfg.sigmoid_bound), scales):
            assert s >= 0, "negative coefficient scale; increase cb"
            out.append(int(round(float(c) * (1 << s))) % field.P)
        return np.asarray(out, dtype=np.int32)

    def update_constants(self, cfg, m: int) -> tuple:
        """(q_eta, e, k1, k2) for the secure truncated update.  All three
        objectives share the eta/m scaling (each model column sees the
        full-batch gradient of its own scalar problem)."""
        from .protocol import derive_update_constants
        return derive_update_constants(cfg, m)

    # ------------------------------------------------------------ targets

    def prepare_targets(self, y) -> np.ndarray:
        """Float target tensor quantized at 2^lg by the protocols: shape
        (m,) + out_shape.  `y` is the dataset's label array."""
        return np.asarray(y, np.float32)

    # ----------------------------------------------------- float reference

    def act_np(self, z):
        """The exact activation ghat approximates (numpy, float64)."""
        raise NotImplementedError

    def act_jnp(self, z):
        """The same activation for jitted float trainers."""
        raise NotImplementedError

    def score(self, w, x, y) -> float:
        """Scalar quality of model `w` on (x, y): classification accuracy
        for the logistic objectives, R^2 for regression."""
        raise NotImplementedError

    def per_class_accuracy(self, w, x, y):
        """(C,) per-class accuracy for matrix models, None otherwise."""
        return None


@dataclasses.dataclass(frozen=True)
class BinaryLogistic(SecureObjective):
    """The paper's objective: binary logreg with the degree-r sigmoid fit."""

    name = "logistic"

    def float_coeffs(self, r: int, bound: float) -> tuple:
        return sigmoid_approx.fit_sigmoid_poly(r, bound)

    def act_np(self, z):
        return 1.0 / (1.0 + np.exp(-z))

    def act_jnp(self, z):
        import jax
        return jax.nn.sigmoid(z)

    def score(self, w, x, y) -> float:
        z = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
        return float(((self.act_np(z) > 0.5) == np.asarray(y)).mean())


@dataclasses.dataclass(frozen=True)
class LinearRegression(SecureObjective):
    """Linear regression: ghat(z) = z exactly, gradient X^T(Xw - y).

    Degree 1 with zero coefficient rounding error (the field coefficient
    of z is exactly 2^cb), hence the lowest recovery threshold the
    protocol admits: R = 3(K+T-1)+1.
    """

    name = "linreg"
    dataset_kind = "regression"

    def validate_cfg(self, cfg) -> None:
        if cfg.r != 1:
            raise ValueError(
                f"linreg's gradient polynomial is exactly degree 1; "
                f"set cfg.r = 1 (got r={cfg.r})")

    def float_coeffs(self, r: int, bound: float) -> tuple:
        return (0.0, 1.0)

    def act_np(self, z):
        return z

    def act_jnp(self, z):
        return z

    def score(self, w, x, y) -> float:
        """R^2 on (x, y) (1 = perfect fit; can go negative early)."""
        x = np.asarray(x, np.float64)
        y = np.asarray(y, np.float64)
        resid = x @ np.asarray(w, np.float64) - y
        denom = float(((y - y.mean()) ** 2).sum()) or 1.0
        return float(1.0 - (resid ** 2).sum() / denom)


@dataclasses.dataclass(frozen=True)
class MulticlassLogistic(SecureObjective):
    """C one-vs-rest logistic regressions as one (d, C) field matrix.

    Targets are the one-hot embedding of integer class labels (each column
    is a binary problem over the SAME rows); prediction is the argmax over
    the C column scores (sigmoid is monotone, so the raw logits argmax is
    the one-vs-rest decision)."""

    n_classes: int = 10

    dataset_kind = "multiclass"

    def __post_init__(self):
        if self.n_classes < 2:
            raise ValueError("multiclass needs n_classes >= 2")

    @property
    def name(self) -> str:
        return f"ovr{self.n_classes}"

    @property
    def n_outputs(self) -> int:
        return self.n_classes

    def float_coeffs(self, r: int, bound: float) -> tuple:
        return sigmoid_approx.fit_sigmoid_poly(r, bound)

    def prepare_targets(self, y) -> np.ndarray:
        labels = np.asarray(y)
        if labels.ndim != 1:
            raise ValueError(f"expected (m,) class labels, got {labels.shape}")
        idx = labels.astype(np.int64)
        if idx.min(initial=0) < 0 or idx.max(initial=0) >= self.n_classes:
            raise ValueError(
                f"class labels must be in [0, {self.n_classes}); got range "
                f"[{idx.min()}, {idx.max()}]")
        return np.eye(self.n_classes, dtype=np.float32)[idx]

    def act_np(self, z):
        return 1.0 / (1.0 + np.exp(-z))

    def act_jnp(self, z):
        import jax
        return jax.nn.sigmoid(z)

    def predict(self, w, x) -> np.ndarray:
        scores = np.asarray(x, np.float64) @ np.asarray(w, np.float64)
        return np.argmax(scores, axis=1)

    def score(self, w, x, y) -> float:
        return float((self.predict(w, x) == np.asarray(y)).mean())

    def per_class_accuracy(self, w, x, y) -> np.ndarray:
        """(C,) per-class recall of the argmax prediction (NaN for classes
        absent from the eval set)."""
        pred = self.predict(w, x)
        labels = np.asarray(y)
        out = np.full(self.n_classes, np.nan)
        for c in range(self.n_classes):
            mask = labels == c
            if mask.any():
                out[c] = float((pred[mask] == c).mean())
        return out


# ------------------------------------------------------------------ registry

OBJECTIVES: dict = {}


def register(obj: SecureObjective, replace: bool = False) -> SecureObjective:
    if not replace and obj.name in OBJECTIVES:
        raise ValueError(f"objective {obj.name!r} already registered")
    OBJECTIVES[obj.name] = obj
    return obj


def get(name: str) -> SecureObjective:
    if name not in OBJECTIVES:
        known = ", ".join(sorted(OBJECTIVES))
        raise KeyError(f"unknown objective {name!r}; registered: {known}")
    return OBJECTIVES[name]


def names() -> tuple:
    return tuple(sorted(OBJECTIVES))


def multiclass_logistic(n_classes: int) -> MulticlassLogistic:
    """An ad-hoc C-class one-vs-rest objective (need not be registered)."""
    return MulticlassLogistic(n_classes=n_classes)


BINARY_LOGISTIC = register(BinaryLogistic())
LINREG = register(LinearRegression())
OVR10 = register(multiclass_logistic(10))
