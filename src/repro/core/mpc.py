"""Share-level MPC primitives (paper Appendix C).

All values are Shamir-shared with threshold T across N clients; share arrays
carry the client axis first: (N, ...).  Operations:

* add / sub / mul-by-public-constant: LOCAL (no communication) -- these are
  the only ops COPML's encode/decode needs (Remark 3), which is the source of
  its speedup over the baselines.
* mul (share x share): requires degree reduction.  Two implementations:
    - BGW [2]:   local product -> re-share -> recombine.   O(N^2) messages.
    - BH08 [3]:  offline pair ([rho]_T, [rho]_2T); online mask, open, re-mask.
                 O(N) broadcasts.
  Both are implemented for real on the share arrays; the cost model in
  cost_model.py accounts their communication.

The "clients" axis is a plain leading array axis here; launch/ maps it onto
the production mesh's data axis with shard_map (each device then literally
holds one client's shares and collectives realize the exchanges).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from . import field, shamir
from .labels import Opened, Share


def add(xs: Share, ys: Share) -> Share:
    return field.add(xs, ys)


def sub(xs: Share, ys: Share) -> Share:
    return field.sub(xs, ys)


def mul_public(xs: Share, c: int) -> Share:
    return field.mul_scalar(xs, c)


def add_public(xs: Share, c: int) -> Share:
    """Add a public constant: by convention added to every share (the
    constant is embedded as the degree-0 coefficient on all shares)."""
    return field.add(xs, jnp.full_like(xs, int(c) % field.P))


def _local_product(xs, ys, matmul: bool):
    if matmul:
        return jax.vmap(field.matmul)(xs, ys)
    return field.mul(xs, ys)


def mul_bgw(key, xs: Share, ys: Share, t: int, *, matmul: bool = False,
            points: Sequence[int] | None = None) -> Share:
    """BGW multiplication: local product (degree 2T shares) + re-share.

    Requires N >= 2T+1.  If matmul=True, xs:(N,A,B) @ ys:(N,B,C).
    """
    n = xs.shape[0]
    assert n >= 2 * t + 1, "BGW needs N >= 2T+1"
    prod = _local_product(xs, ys, matmul)
    return shamir.reshare(key, prod, t, n, points)


def mul_bh08(key, xs: Share, ys: Share, t: int, *, matmul: bool = False,
             points: Sequence[int] | None = None) -> Share:
    """[BH08] multiplication with an offline random pair.

    Offline: rho random; [rho]_T and [rho]_2T dealt.
    Online:  open d = x*y - rho from degree-2T shares (needs 2T+1 of them),
             output [rho]_T + d  (local add of a now-public value).
    """
    n = xs.shape[0]
    assert n >= 2 * t + 1, "BH08 needs N >= 2T+1 to open the 2T-degree mask"
    if points is None:
        points = shamir.default_eval_points(n)
    prod = _local_product(xs, ys, matmul)  # (N, ...) degree-2T shares
    k_rho, k_t, k_2t = jax.random.split(key, 3)
    rho = field.random_field(k_rho, prod.shape[1:])
    rho_t = shamir.share(k_t, rho, t, n, points)
    rho_2t = shamir.share(k_2t, rho, 2 * t, n, points)
    masked = field.sub(prod, rho_2t)
    # "broadcast and open": interpolate the degree-2T sharing at z=0
    opened = shamir.reconstruct(masked, 2 * t, points)
    return field.add(rho_t, opened[None])


def open_shares(xs: Share, t: int, points: Sequence[int] | None = None,
                subset: Sequence[int] | None = None) -> Opened:
    """Publicly reconstruct a shared value (e.g. the final model w^(J))."""
    return shamir.reconstruct(xs, t, points, subset)
