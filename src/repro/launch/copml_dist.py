"""COPML on a real device mesh: the distributed protocol entry point.

The paper's N clients map onto a 1-D ("clients",) mesh (each device holds a
contiguous block of clients' shares and coded slices) and the protocol runs
under shard_map (core/protocol.py, Copml.train_sharded), so every exchange
is an explicit collective rather than a GSPMD annotation:

  share distribution (owner -> holder transpose)   -> all_to_all
  model-encoding reconstruction (sum over holders) -> mod-p reduce-scatter
  TruncPr / model opening                          -> all_gather + replicated
                                                      decode

Run it for real on a CPU host (flag must precede the first jax import):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.copml_dist --devices 8 --clients 13 --iters 5

which trains api.fit(..., engine="sharded") over the mesh, re-trains on one
device with engine="jit", and asserts the two are bit-exact.  --bench
prints the CSV rows benchmarks/run.py's `distributed` stage records.

Dry-run cells (invoked from launch/dryrun.py for --arch copml-logreg) lower
and compile ONE real sharded iteration -- collectives and all -- on the
flattened production mesh; shape names map to paper-scale and pod-scale
workloads:

  train_4k    -> CIFAR-10 scale (m=9019, d=3073), paper Case 2 at N=mesh size
  prefill_32k -> GISETTE scale (m=6000, d=5000)
  decode_32k  -> pod-scale (m=262144, d=4096)
  smoke       -> tiny (m=416, d=64), used by tests/test_distributed.py
  long_500k   -> skipped (no analogue; noted in DESIGN.md)
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import meshutil
from ..core.protocol import Copml, CopmlConfig, CopmlState, case2_params
from ..sharding import partition
from . import roofline as RL

_SHAPE_MAP = {
    "train_4k": ("cifar10-scale", 9019, 3073),
    "prefill_32k": ("gisette-scale", 6000, 5000),
    "decode_32k": ("pod-scale", 262144, 4096),
    "smoke": ("smoke-scale", 416, 64),
}

# field MACs per train iteration (Table II, matvec-chain evaluation):
# encode w: d*N*(K+T); local grad: 2*(m/K)*d per client; decode: d*R per
# block; all clients in parallel.  1 field MAC ~ 16 f32 MXU MACs + ~40 int32
# VPU ops under the limb decomposition (DESIGN.md section 3.2); we price it
# at 16 MXU-equivalent flops for the compute term.
FIELD_MAC_FLOPS = 16.0


def make_config(n: int, m: int, d: int) -> CopmlConfig:
    k, t = case2_params(n)
    # The truncation depth k1 = 2*lx + cb + log2(m/eta) must stay below
    # log2(p): with the paper's 26-bit field, m beyond ~2^14 forces either
    # coarser quantization or a larger step size.  We scale eta with m
    # (documented scalability limit of the 26-bit field, EXPERIMENTS.md).
    eta = max(1.0, m / 4096.0)
    return CopmlConfig(n_clients=n, k=k, t=t, eta=eta)


def make_protocol(n: int, m: int, d: int) -> Copml:
    return Copml(make_config(n, m, d), m, d)


def flatten_mesh(mesh):
    """Any production mesh -> the 1-D ("clients",) mesh of the same devices."""
    if tuple(mesh.axis_names) == (meshutil.CLIENT_AXIS,):
        return mesh
    return meshutil.client_mesh(devices=list(mesh.devices.reshape(-1)))


def state_structs(proto: Copml, mesh) -> CopmlState:
    """Abstract padded client-sharded CopmlState; the client NamedSharding
    is built in ONE place, sharding/partition.copml_state_structs."""
    return partition.copml_state_structs(proto, mesh)


def dryrun_cell(shape_name: str, mesh, multi_pod: bool) -> dict:
    """Compile one REAL sharded iteration (shard_map + collectives) for the
    given mesh and report per-device memory + roofline, no data needed."""
    if shape_name not in _SHAPE_MAP:
        return {"arch": "copml-logreg", "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped (no long-context analogue for secure "
                          "logistic regression)"}
    tag, m, d = _SHAPE_MAP[shape_name]
    n = mesh.size
    cmesh = flatten_mesh(mesh)
    proto = make_protocol(n, m, d)
    cfg = proto.cfg
    step_fn, _ = proto.sharded_step(cmesh)
    state = state_structs(proto, cmesh)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                               sharding=NamedSharding(cmesh, P()))
    lowered = jax.jit(step_fn).lower(state.w_shares, state.coded_x,
                                     state.xty_shares, key)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    mk = -(-m // cfg.k)
    macs = (d * n * (cfg.k + cfg.t)            # encode w
            + 2.0 * mk * d                      # local coded gradient
            + d * cfg.recovery_threshold * cfg.k  # decode
            ) * n                               # per client, N clients
    mflops = macs * FIELD_MAC_FLOPS
    rf = RL.analyze(f"copml/{tag}", compiled, mesh.size, mflops)
    rec = rf.to_dict()
    rec.update({
        "arch": "copml-logreg", "shape": shape_name, "workload": tag,
        "mesh": "multipod" if multi_pod else "pod", "status": "ok",
        "n_clients": n, "K": cfg.k, "T": cfg.t,
        "recovery_threshold": cfg.recovery_threshold,
        "collectives": RL.collective_bytes(compiled.as_text())["counts"],
        "bytes_per_device": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "peak": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes),
        },
    })
    print(f"--- copml-logreg[{tag}] x {'multipod(512)' if multi_pod else 'pod(256)'}"
          f" N={n} K={cfg.k} T={cfg.t} R={cfg.recovery_threshold} ---")
    print(f"memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
    print(f"collectives: {rec['collectives']}")
    print(f"roofline: compute={rf.compute_s*1e3:.3f}ms "
          f"memory={rf.memory_s*1e3:.3f}ms "
          f"collective={rf.collective_s*1e3:.3f}ms dominant={rf.dominant}")
    return rec


# ------------------------------------------------------------------ CLI


def _workload(args):
    """Ad-hoc api workload for the CLI's (m, d, clients) arguments."""
    from .. import api
    return api.Workload(
        name=f"cli_m{args.m}_d{args.d}_n{args.clients}", m=args.m, d=args.d,
        cfg=make_config(args.clients, args.m, args.d), iters=args.iters)


def run_parity(args) -> None:
    """Train sharded on the client mesh, re-train single-device, compare.

    Both runs go through api.fit -- the same facade path every other
    driver uses; only the engine axis differs.  With --straggle-p the SAME
    seeded FaultPlan is replayed by both engines (mid-training churn over
    real collectives, still bit-exact)."""
    from .. import api
    wl = _workload(args)
    cfg = wl.cfg
    mesh = meshutil.client_mesh(args.devices)
    plan = None
    if args.straggle_p is not None:
        # the SAME threshold api.fit's plan validation enforces
        thr = api.PROTOCOLS["copml"].fault_threshold(wl)
        plan = api.FaultPlan.random(
            cfg.n_clients, args.iters, seed=args.fault_seed,
            straggle_p=args.straggle_p, min_available=thr)
        print(plan.describe(thr))
    print(f"COPML distributed: N={cfg.n_clients} clients over "
          f"{mesh.size} devices, K={cfg.k} T={cfg.t} "
          f"R={cfg.recovery_threshold}, {args.iters} iterations")
    res_s = api.fit(wl, "copml", api.EngineSpec("sharded", mesh=mesh),
                    key=args.seed, iters=args.iters, history=False,
                    faults=plan)
    res_j = api.fit(wl, "copml", "jit", key=args.seed, iters=args.iters,
                    history=False, faults=plan)
    np.testing.assert_array_equal(res_s.weights, res_j.weights)
    np.testing.assert_array_equal(np.asarray(res_s.state.w_shares),
                                  np.asarray(res_j.state.w_shares))
    print(f"bit-exact: sharded == jit  "
          f"(sharded {res_s.wall_time_s:.2f}s incl. compile, "
          f"single {res_j.wall_time_s:.2f}s)")


def run_bench(args, report=print) -> None:
    """Sharded-vs-single-device wall time, interleaved best-of-reps
    (both warm; virtual CPU devices share the host's cores, so this
    measures protocol+collective overhead, not real multi-chip scaling)."""
    from .. import api
    wl = _workload(args)
    mesh = meshutil.client_mesh(args.devices)
    engines = (("train_jit_1dev", "jit"),
               (f"train_sharded_{mesh.size}dev",
                api.EngineSpec("sharded", mesh=mesh)))
    best = {}
    for name, eng in engines:                   # compile + warm
        api.fit(wl, "copml", eng, key=args.seed, iters=args.iters,
                history=False)
        best[name] = float("inf")
    for _ in range(args.reps):                  # interleaved best-of-reps
        for name, eng in engines:
            res = api.fit(wl, "copml", eng, key=args.seed, iters=args.iters,
                          history=False)
            best[name] = min(best[name], res.wall_time_s)
    base = best[engines[0][0]]
    for name, _ in engines:
        dt = best[name]
        report(f"copml_dist/{name}_{args.iters}it,{dt * 1e6:.1f},"
               f"{base / dt:.2f}x_vs_1dev")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size (default: all visible devices)")
    ap.add_argument("--clients", type=int, default=13)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--m", type=int, default=832)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggle-p", type=float, default=None,
                    help="replay a seeded FaultPlan (mid-training churn) "
                         "on both engines of the parity demo")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--bench", action="store_true",
                    help="print benchmark CSV rows instead of the parity demo")
    args = ap.parse_args(argv)
    if args.devices is None:
        args.devices = len(jax.devices())
    if len(jax.devices()) < 2:
        print("NOTE: only one device visible; set "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8 "
              "before launching to exercise real collectives.")
    if args.bench:
        run_bench(args)
    else:
        run_parity(args)


if __name__ == "__main__":
    main()
