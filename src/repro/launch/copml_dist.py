"""COPML on the production mesh: one client per device.

The paper's N clients map onto the flattened mesh (DESIGN.md section 3.1):
every share/coded array carries the client axis first, sharded over ALL mesh
axes, so each device holds exactly what a real client would hold.  The
protocol's exchanges lower to collectives under GSPMD:

  share distribution (owner, holder) transpose  -> all-to-all
  reconstruction (matmul over the client axis)  -> reduce-scatter/all-reduce
  share-of-sum aggregation                      -> all-reduce

Dry-run cells (invoked from launch/dryrun.py for --arch copml-logreg):
shape names map to paper-scale and pod-scale workloads:

  train_4k    -> CIFAR-10 scale (m=9019, d=3073), paper Case 2 at N=mesh size
  prefill_32k -> GISETTE scale (m=6000, d=5000)
  decode_32k  -> pod-scale (m=262144, d=4096)
  long_500k   -> skipped (no analogue; noted in DESIGN.md)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core import field, meshutil
from ..core.protocol import Copml, CopmlConfig, CopmlState, case2_params
from . import roofline as RL

_SHAPE_MAP = {
    "train_4k": ("cifar10-scale", 9019, 3073),
    "prefill_32k": ("gisette-scale", 6000, 5000),
    "decode_32k": ("pod-scale", 262144, 4096),
}

# field MACs per train iteration (Table II, matvec-chain evaluation):
# encode w: d*N*(K+T); local grad: 2*(m/K)*d per client; decode: d*R per
# block; all clients in parallel.  1 field MAC ~ 16 f32 MXU MACs + ~40 int32
# VPU ops under the limb decomposition (DESIGN.md section 3.2); we price it
# at 16 MXU-equivalent flops for the compute term.
FIELD_MAC_FLOPS = 16.0


def make_protocol(n: int, m: int, d: int) -> Copml:
    k, t = case2_params(n)
    # The truncation depth k1 = 2*lx + cb + log2(m/eta) must stay below
    # log2(p): with the paper's 26-bit field, m beyond ~2^14 forces either
    # coarser quantization or a larger step size.  We scale eta with m
    # (documented scalability limit of the 26-bit field, EXPERIMENTS.md).
    eta = max(1.0, m / 4096.0)
    cfg = CopmlConfig(n_clients=n, k=k, t=t, eta=eta)
    return Copml(cfg, m, d)


def client_sharding(mesh):
    """Client axis over every mesh axis: one client per device."""
    return NamedSharding(mesh, P(tuple(mesh.axis_names)))


def state_structs(proto: Copml, mesh):
    n, d = proto.cfg.n_clients, proto.d
    mk = -(-proto.m // proto.cfg.k)
    cl = client_sharding(mesh)
    sds = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32, sharding=cl)
    return CopmlState(
        w_shares=sds((n, d)),
        coded_x=sds((n, mk, d)),
        xty_shares=sds((n, d)),
        step=jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P())),
    )


def dryrun_cell(shape_name: str, mesh, multi_pod: bool) -> dict:
    if shape_name not in _SHAPE_MAP:
        return {"arch": "copml-logreg", "shape": shape_name,
                "mesh": "multipod" if multi_pod else "pod",
                "status": "skipped (no long-context analogue for secure "
                          "logistic regression)"}
    tag, m, d = _SHAPE_MAP[shape_name]
    n = mesh.size
    proto = make_protocol(n, m, d)
    cfg = proto.cfg
    state = state_structs(proto, mesh)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32,
                               sharding=NamedSharding(mesh, P()))
    with meshutil.set_mesh(mesh):
        lowered = jax.jit(proto.iteration).lower(key, state)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    mk = -(-m // cfg.k)
    macs = (d * n * (cfg.k + cfg.t)            # encode w
            + 2.0 * mk * d                      # local coded gradient
            + d * cfg.recovery_threshold * cfg.k  # decode
            ) * n                               # per client, N clients
    mflops = macs * FIELD_MAC_FLOPS
    rf = RL.analyze(f"copml/{tag}", compiled, mesh.size, mflops)
    rec = rf.to_dict()
    rec.update({
        "arch": "copml-logreg", "shape": shape_name, "workload": tag,
        "mesh": "multipod" if multi_pod else "pod", "status": "ok",
        "n_clients": n, "K": cfg.k, "T": cfg.t,
        "recovery_threshold": cfg.recovery_threshold,
        "bytes_per_device": {
            "argument": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "peak": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes),
        },
    })
    print(f"--- copml-logreg[{tag}] x {'multipod(512)' if multi_pod else 'pod(256)'}"
          f" N={n} K={cfg.k} T={cfg.t} R={cfg.recovery_threshold} ---")
    print(f"memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
          f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
    print(f"roofline: compute={rf.compute_s*1e3:.3f}ms "
          f"memory={rf.memory_s*1e3:.3f}ms "
          f"collective={rf.collective_s*1e3:.3f}ms dominant={rf.dominant}")
    return rec
