"""Production mesh construction.

Importing this module never touches jax device state; call
make_production_mesh() only after the launcher has configured the platform
(dryrun.py sets XLA_FLAGS before any jax import)."""

from __future__ import annotations

import jax

from ..core import meshutil


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod mesh: 16x16 = 256 chips per pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return meshutil.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Whatever this host actually has (tests / examples)."""
    n = len(jax.devices())
    mp = min(model_parallel, n)
    return meshutil.make_mesh((n // mp, mp), ("data", "model"))
