import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, SPMD-partitions, and compiles for the production meshes.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape train_4k --mesh pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/

Per cell we print compiled.memory_analysis() (fits-in-HBM proof) and
cost_analysis() (FLOPs/bytes for the roofline), and append a JSON record
consumed by benchmarks/roofline_report.py.
"""

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from ..configs import registry
from ..core import meshutil
from ..models import model_zoo as MZ
from ..models.config import applicable_shapes, ALL_SHAPES
from ..sharding import partition
from . import mesh as mesh_lib
from . import roofline as RL


DEFAULT_MICROBATCH_DIV = 8   # global batch / 8 per accumulation step
DEFAULT_LOSS_CHUNK = 512     # seq-chunked CE: never materialize (B,S,V)


def _step_fn_and_args(cfg, shape, mesh, *, loss_chunk=None, microbatch=None,
                      remat=None):
    """Returns (fn, args) ready for jax.jit(fn).lower(*args)."""
    if remat is not None:
        cfg = cfg.scaled(remat=remat)
    if microbatch is None:
        microbatch = max(1, shape.global_batch // DEFAULT_MICROBATCH_DIV) \
            if shape.kind == "train" else 0
    if loss_chunk is None:
        loss_chunk = DEFAULT_LOSS_CHUNK if shape.kind == "train" else 0
    bm = MZ.build(cfg, microbatch=microbatch, loss_chunk=loss_chunk)
    if shape.kind == "train":
        params = partition.param_structs(cfg, mesh)
        opt = partition.opt_state_structs(cfg, mesh, params)
        batch = partition.batch_structs(cfg, shape, mesh)
        step = jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=partition.replicated(mesh))
        return bm.train_step, (params, opt, batch, step)
    if shape.kind == "prefill":
        params = partition.param_structs(cfg, mesh)
        batch = partition.batch_structs(cfg, shape, mesh)
        return bm.prefill_step, (params, batch)
    # decode: no gradients -- params use the data axis too (inference FSDP)
    params = partition.param_structs(
        cfg, mesh, fsdp=(cfg.param_count() * 2 / mesh.shape.get("model", 1)
                         > 2 ** 32))
    caches = partition.cache_structs(cfg, shape, mesh)
    batch = partition.batch_structs(cfg, shape, mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=partition.replicated(mesh))
    return bm.decode_step, (params, caches, batch["tokens"], pos)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir=None,
             **tuning) -> dict:
    t0 = time.perf_counter()
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    if arch == "copml-logreg":
        from . import copml_dist
        rec = copml_dist.dryrun_cell(shape_name, mesh, multi_pod)
    else:
        cfg = registry.get_config(arch)
        shape = {s.name: s for s in ALL_SHAPES}[shape_name]
        if shape not in applicable_shapes(cfg):
            return {"arch": arch, "shape": shape_name,
                    "mesh": "multipod" if multi_pod else "pod",
                    "status": "skipped (full attention at 500k context, "
                              "DESIGN.md section 6)"}
        fn, args = _step_fn_and_args(cfg, shape, mesh, **tuning)
        with meshutil.set_mesh(mesh):
            lowered = jax.jit(fn).lower(*args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        mflops = RL.model_flops(cfg, shape)
        rf = RL.analyze(f"{arch}/{shape_name}", compiled, chips, mflops)
        rec = rf.to_dict()
        rec.update({
            "arch": arch, "shape": shape_name,
            "mesh": "multipod" if multi_pod else "pod",
            "status": "ok",
            "bytes_per_device": {
                "argument": mem.argument_size_in_bytes,
                "output": mem.output_size_in_bytes,
                "temp": mem.temp_size_in_bytes,
                "peak": (mem.argument_size_in_bytes
                         + mem.output_size_in_bytes
                         + mem.temp_size_in_bytes),
            },
            "collectives": RL.collective_bytes(compiled.as_text())["counts"],
        })
        print(f"--- {arch} x {shape_name} x "
              f"{'multipod(512)' if multi_pod else 'pod(256)'} ---")
        print(f"memory_analysis: args={mem.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={mem.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB")
        print(f"cost_analysis: flops={rf.hlo_flops:.3e} "
              f"bytes={rf.hlo_bytes:.3e} "
              f"coll_bytes/dev={rf.coll_bytes_per_device:.3e}")
        print(f"roofline: compute={rf.compute_s*1e3:.3f}ms "
              f"memory={rf.memory_s*1e3:.3f}ms "
              f"collective={rf.collective_s*1e3:.3f}ms "
              f"dominant={rf.dominant} "
              f"useful_ratio={rf.useful_flops_ratio:.3f} "
              f"roofline_frac={rf.roofline_fraction:.3f}")
    rec["compile_s"] = time.perf_counter() - t0
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}_{shape_name}_{'multipod' if multi_pod else 'pod'}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=registry.ARCH_IDS)
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES] + ["all"])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod",
                                                      "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = registry.ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = [s.name for s in ALL_SHAPES] \
        if args.all or args.shape in (None, "all") else [args.shape]
    meshes = {"pod": (False,), "multipod": (True,),
              "both": (False, True)}[args.mesh]
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    rec = run_cell(arch, shape, mp, args.out)
                    if "skipped" in rec.get("status", ""):
                        print(f"SKIP {arch} x {shape}: {rec['status']}")
                except Exception as e:  # noqa: BLE001 -- report and continue
                    failures.append((arch, shape, mp, repr(e)[:200]))
                    print(f"FAIL {arch} x {shape} multipod={mp}: {e!r}",
                          file=sys.stderr)
    if failures:
        print(f"{len(failures)} failures", file=sys.stderr)
        sys.exit(1)
    print("dry-run: all requested cells compiled")


if __name__ == "__main__":
    main()
