"""Length-prefixed framed wire format for the multi-process runtime.

One frame =

    offset  size  field
    0       2     magic  b"CW"
    2       1     version (1)
    3       1     kind    (net.py's frame-kind enum)
    4       2     src     sender rank (0xFFFF = coordinator)
    6       2     tag     sub-channel within a kind (TRUNC/HIST/...)
    8       4     step    GD iteration the payload belongs to
    12      4     length  payload byte count
    16      len   payload

Big-endian throughout; `FrameReader` reassembles frames from arbitrary
stream chunkings and raises `WireError` on a bad magic, an unknown
version, an oversized length, or a stream that ends mid-frame
(tests/test_runtime_transport.py).

Array payloads travel as a tiny self-describing header (dtype + shape)
followed by the raw C-order bytes -- `pack_array`/`unpack_array`.  No
pickle on the hot path: array frames are fixed-format and cannot execute
anything on receipt.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy

MAGIC = b"CW"
VERSION = 1
HEADER = struct.Struct("!2sBBHHII")
HEADER_SIZE = HEADER.size          # 16 bytes
MAX_PAYLOAD = 1 << 28              # 256 MiB: far above any COPML frame
_MAX_NDIM = 8


class WireError(ValueError):
    """Malformed frame: bad magic/version, oversized, or truncated."""


@dataclasses.dataclass(frozen=True)
class Frame:
    kind: int
    src: int
    tag: int
    step: int
    payload: bytes

    def __len__(self) -> int:
        return HEADER_SIZE + len(self.payload)


def encode_frame(kind: int, src: int, tag: int, step: int,
                 payload: bytes = b"") -> bytes:
    if len(payload) > MAX_PAYLOAD:
        raise WireError(f"payload of {len(payload)} bytes exceeds "
                        f"MAX_PAYLOAD ({MAX_PAYLOAD})")
    return HEADER.pack(MAGIC, VERSION, kind, src, tag, step,
                       len(payload)) + payload


class FrameReader:
    """Incremental frame parser over an arbitrarily-chunked byte stream."""

    def __init__(self, max_payload: int = MAX_PAYLOAD):
        self._buf = bytearray()
        self._max = max_payload

    @property
    def pending(self) -> int:
        """Bytes buffered but not yet parsed into a full frame."""
        return len(self._buf)

    def feed(self, data: bytes) -> list:
        """Consume a chunk; return every frame it completes (in order)."""
        self._buf += data
        frames = []
        while len(self._buf) >= HEADER_SIZE:
            magic, ver, kind, src, tag, step, length = HEADER.unpack_from(
                self._buf)
            if magic != MAGIC:
                raise WireError(f"bad magic {bytes(magic)!r} "
                                f"(expected {MAGIC!r})")
            if ver != VERSION:
                raise WireError(f"unknown wire version {ver}")
            if length > self._max:
                raise WireError(f"frame length {length} exceeds cap "
                                f"{self._max}")
            if len(self._buf) < HEADER_SIZE + length:
                break
            payload = bytes(self._buf[HEADER_SIZE:HEADER_SIZE + length])
            del self._buf[:HEADER_SIZE + length]
            frames.append(Frame(kind, src, tag, step, payload))
        return frames

    def close(self):
        """Signal end-of-stream; a buffered partial frame is an error."""
        if self._buf:
            raise WireError(f"stream truncated mid-frame "
                            f"({len(self._buf)} dangling bytes)")


# ------------------------------------------------------------- array payloads

_ARR_HEAD = struct.Struct("!BB")


def pack_array(arr) -> bytes:
    """numpy array -> self-describing bytes (dtype, shape, raw C-order)."""
    # asarray(order="C"), not ascontiguousarray: the latter silently
    # promotes 0-d arrays to shape (1,), breaking the round trip
    a = numpy.asarray(arr, order="C")
    if a.ndim > _MAX_NDIM:
        raise WireError(f"array rank {a.ndim} exceeds {_MAX_NDIM}")
    dt = a.dtype.str.encode("ascii")
    return (_ARR_HEAD.pack(len(dt), a.ndim) + dt
            + struct.pack(f"!{a.ndim}Q", *a.shape) + a.tobytes())


def unpack_array(data: bytes):
    """Inverse of pack_array; validates the length before reshaping."""
    if len(data) < _ARR_HEAD.size:
        raise WireError("array payload shorter than its header")
    dt_len, ndim = _ARR_HEAD.unpack_from(data)
    if ndim > _MAX_NDIM:
        raise WireError(f"array rank {ndim} exceeds {_MAX_NDIM}")
    off = _ARR_HEAD.size
    dtype = numpy.dtype(data[off:off + dt_len].decode("ascii"))
    off += dt_len
    shape = struct.unpack_from(f"!{ndim}Q", data, off)
    off += 8 * ndim
    count = 1
    for s in shape:
        count *= s
    if len(data) - off != count * dtype.itemsize:
        raise WireError(f"array payload carries {len(data) - off} data "
                        f"bytes; shape {shape} x {dtype} needs "
                        f"{count * dtype.itemsize}")
    return numpy.frombuffer(data, dtype=dtype, offset=off,
                            count=count).reshape(shape)


def share_payload(shares) -> bytes:
    """THE sanctioned cross-process share sink (seclint: declassify).

    Shamir/LCC shares leaving the process to an authorized holder over
    the runtime's links IS the protocol (PAPER.md Phases 2/4): each
    holder receives exactly the evaluations addressed to it, the same
    standing an in-process `-> Opened` reconstruction has.  Registered
    as a declassify sink in analysis/registry.py; any OTHER socket or
    pickle write of a Share still flags SEC001/SEC003
    (tests/fixtures/seclint/procsend_bad.py).
    """
    return pack_array(numpy.asarray(shares))
