"""Worker process: one COPML client group's compute + socket collectives.

Each worker owns `n_loc = ceil(N / P)` consecutive clients and runs the
exact per-step math of the mesh-sharded engine
(core/protocol.Copml._sharded_scan) with every mesh collective replaced
by its socket equivalent:

    reduce-scatter (model encode)   peer-to-peer ENC partial rows,
                                    chained field.add (exact mod-p sum)
    all_to_all (gradient shares)    peer-to-peer SHARE blocks
    all_gather + open (TruncPr)     OPEN rows to the coordinator,
                                    OPENED broadcast back

Bit-exactness with the jit engine holds for the same reason the sharded
engine's does: every random draw is replicated dealer randomness (same
key, full global shape on every process -- the paper's offline crypto
provider, fn. 3) and every cross-process contraction is an exact mod-p
linear reduction.  The decode subset may differ per step (whichever
owners' blocks arrive before the deadline); LCC decoding is exact
polynomial interpolation, so ANY >= R-subset yields identical values --
the invariance PR 4's fault engine proved, now exercised by real
network timing.
"""

from __future__ import annotations

import contextlib
import json
import pickle
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from ...core import field, lagrange, shamir, truncation
from ...core.protocol import Copml
from . import net, wire


class _PhaseClock:
    """Cumulative wall-time per protocol phase (the measured side of
    ARCHITECTURE.md's modeled-vs-measured comparison)."""

    def __init__(self):
        self.seconds: dict = {}

    @contextlib.contextmanager
    def __call__(self, phase: str):
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.seconds[phase] = (self.seconds.get(phase, 0.0)
                                   + time.perf_counter() - t0)


def worker_entry(rank: int, coord_host: str, coord_port: int):
    """Worker main: handshake, run the session, report, exit.

    Launched as `python -m repro.launch.runtime.worker RANK HOST PORT`
    (a plain subprocess: nothing of the parent's __main__ is re-imported,
    so the engine works from scripts, notebooks, and stdin alike)."""
    node = net.Node(rank)
    node.start(listen=True)
    try:
        node.connect(net.COORD, coord_host, coord_port)
        node.send(net.COORD, net.LISTEN, payload=pickle.dumps(
            {"host": node.cfg.host, "port": node.port}))
        sess = pickle.loads(
            node.recv(net.SESSION, src=net.COORD, retries=1,
                      timeout=node.cfg.spawn_timeout_s).payload)
        node.configure(sess["net"])
        _run_session(node, sess)
        node.recv(net.BYE, src=net.COORD)
    except net.PeerFailure:
        raise SystemExit(1)          # the coordinator already knows
    except Exception:  # noqa: BLE001 -- report ANY failure upstream
        try:
            # ERR is a plain-scalar UTF-8 JSON control frame (never
            # pickle: the coordinator must not unpickle from a possibly
            # compromised worker).
            node.send(net.COORD, net.ERR, payload=json.dumps(
                {"rank": rank, "error": traceback.format_exc()},
            ).encode("utf-8"))
            time.sleep(0.2)          # let the frame flush before exit
        except Exception:  # noqa: BLE001
            pass
        raise SystemExit(1)
    finally:
        node.stop()


def _run_session(node: net.Node, sess: dict):
    t_start = time.perf_counter()
    rank = node.rank
    proto = Copml(sess["cfg"], sess["m"], sess["d"],
                  objective=sess["objective"])
    cfg = proto.cfg
    n, P = cfg.n_clients, sess["n_procs"]
    n_loc = -(-n // P)
    n_pad = n_loc * P
    t_, kk, dw, w_shape = cfg.t, cfg.k, proto.dw, proto.w_shape
    lo = rank * n_loc
    rthr = cfg.recovery_threshold
    iters, history = sess["iters"], sess["history"]
    forced = sess["subset"]          # decode subset pinned by the caller

    def real_count(r):
        """Non-padded clients owned by rank r (trailing rank may own
        fewer when P does not divide N)."""
        return max(0, min(n_loc, n - r * n_loc))

    # full-mesh links: rank i dials every lower rank, higher ranks dial us
    for peer in range(P):
        if peer < rank:
            host, port = sess["addrs"][peer]
            node.connect(peer, host, port)
    node.send(net.COORD, net.READY)
    node.recv(net.START, src=net.COORD,
              timeout=node.cfg.spawn_timeout_s, retries=1)

    # public per-client constants, zero-padded exactly like _sharded_scan
    pmat_np = np.zeros((n_pad, t_), np.int32)
    pmat_np[:n] = shamir._power_matrix(tuple(proto.lambdas), t_)
    wall_np = np.zeros((n_pad,), np.int32)
    wall_np[:n] = shamir._recon_matrix(tuple(proto.lambdas))[0]
    pmat_all = jnp.asarray(pmat_np)
    pmat_loc = jnp.asarray(pmat_np[lo:lo + n_loc])
    wall_loc = jnp.asarray(wall_np[lo:lo + n_loc])

    w_loc = jnp.asarray(wire.unpack_array(sess["w_rows"]))
    coded_x = jnp.asarray(wire.unpack_array(sess["coded_rows"]))
    xty_loc = jnp.asarray(wire.unpack_array(sess["xty_rows"]))
    key = jnp.asarray(sess["key"])

    clock = _PhaseClock()
    dvec_cache: dict = {}
    degraded = 0

    def share_rows(keyc, secret):
        """This rank's holder rows of shamir.share(keyc, secret, t, n):
        replicated coefficient draw, shard-local power-matrix rows."""
        coeffs = field.random_field(keyc, (t_,) + secret.shape)
        mix = field.matmul(pmat_loc, coeffs.reshape(t_, -1))
        return field.add(mix.reshape((n_loc,) + secret.shape), secret[None])

    def open_via_coord(c_sh, step):
        """TruncPr's masked opening: gather at the coordinator, get the
        reconstruction broadcast back (the OPEN barrier round)."""
        with clock("trunc_open"):
            node.send(net.COORD, net.OPEN, step=step, tag=net.TAG_TRUNC,
                      payload=wire.share_payload(c_sh), phase="trunc_open")
            frm = node.recv(net.OPENED, src=net.COORD, step=step,
                            tag=net.TAG_TRUNC)
        return jnp.asarray(wire.unpack_array(frm.payload))

    def encode_model(k1_, w_c, step):
        """Per-iteration model encode; the reconstruct-from-all-holders
        contraction runs as a socket reduce-scatter: each rank weights
        its own holders' encodings, sends peer s the partial for s's
        clients, and field.adds the partials it receives (chained exact
        mod-p addition == psum_scatter_mod's sum-then-reduce).

        Each peer's partial is a row slice of the weighted contraction,
        so it is computed JUST before its send: peer s's frame is on the
        wire while the GEMM for peer s+1 runs, instead of every byte
        waiting behind the monolithic (n_pad, dw) matmul.  Same frames,
        same order, same payload bits (a row slice of a matmul is the
        same contraction) -- commlint's budget holds unchanged."""
        with clock("encode"):
            kv, ks_ = jax.random.split(k1_)
            v = field.random_field(kv, (t_,) + w_shape)
            v_sh = share_rows(ks_, v)
            w_flat = w_c.reshape(n_loc, dw)
            v_flat = v_sh.reshape(n_loc, t_, dw)
            blocks = jnp.broadcast_to(w_flat[:, None], (n_loc, kk, dw))
            enc = jax.vmap(lambda b, vv: lagrange.lcc_encode(
                b[:, None, :], vv[:, None, :], proto.alphas, proto.betas
            )[:, 0, :])(blocks, v_flat)                      # (n_loc, N, dw)
            if n_pad > n:
                enc = jnp.concatenate(
                    [enc, jnp.zeros((n_loc, n_pad - n, dw), jnp.int32)],
                    axis=1)

            def seg(s):
                sl = enc[:, s * n_loc:(s + 1) * n_loc]
                return field.matmul(
                    wall_loc[None, :],
                    sl.reshape(n_loc, -1)).reshape(n_loc, dw)

            for s in range(P):
                if s == rank:
                    continue
                node.send(s, net.ENC, step=step,
                          payload=wire.share_payload(seg(s)), phase="encode")
            acc = seg(rank)
            for s in range(P):
                if s == rank:
                    continue
                frm = node.recv(net.ENC, src=s, step=step)
                acc = field.add(
                    acc, jnp.asarray(wire.unpack_array(frm.payload)))
        return acc                                           # (n_loc, dw)

    def collect_blocks(blocks, step):
        """Gather SHARE blocks and pick this step's decode subset from
        what actually ARRIVED -- straggling emerges from the network.

        With a pinned subset, wait (recv timeout policy) for exactly the
        ranks covering it.  Otherwise wait for everyone, but once >= R
        real owners are in hand, give the rest decode_timeout_s (or the
        recv budget) before decoding from the survivors."""
        nonlocal degraded
        if forced is not None:
            for s in sorted({g // n_loc for g in forced} - set(blocks)):
                frm = node.recv(net.SHARE, src=s, step=step)
                blocks[s] = jnp.asarray(wire.unpack_array(frm.payload))
            return tuple(forced)[:rthr]
        cfg_net = node.cfg
        soft = None if cfg_net.decode_timeout_s is None else (
            time.monotonic() + cfg_net.decode_timeout_s)
        hard = time.monotonic() + (cfg_net.recv_timeout_s
                                   * max(1, cfg_net.recv_retries))
        while len(blocks) < P:
            covered = sum(real_count(s) for s in blocks)
            now = time.monotonic()
            if covered >= rthr and (now >= hard
                                    or (soft is not None and now >= soft)):
                degraded += 1
                break
            if covered < rthr and now >= hard:
                raise net.NodeTimeout(
                    f"rank {rank}: only {covered} of the {rthr} owner "
                    f"blocks needed to decode step {step} arrived")
            frm = node.recv_any(net.SHARE, step, timeout=0.01)
            if frm is not None:
                blocks[frm.src] = jnp.asarray(
                    wire.unpack_array(frm.payload))
        owners = sorted(g for s in blocks
                        for g in range(s * n_loc, s * n_loc + real_count(s)))
        return tuple(owners[:rthr])

    def decode_update(k2_, w_c, f_loc, step):
        """Phase 4: share the coded gradients (all_to_all over sockets),
        decode locally from the arrived subset, TruncPr update."""
        kf, kt = jax.random.split(k2_)
        # replicated global sharing-polynomial draw, own columns kept
        coeffs = field.random_field(kf, (t_, n) + w_shape)
        coeffs = coeffs.reshape(t_, n, dw)
        if n_pad > n:
            coeffs = jnp.concatenate(
                [coeffs, jnp.zeros((t_, n_pad - n, dw), jnp.int32)], axis=1)
        cl = coeffs[:, lo:lo + n_loc]
        f_flat = f_loc.reshape(n_loc, dw)

        def mine_block(s):
            # holder rows owned by rank s, built just before the send so
            # the SHARE frame for s rides the wire while s+1's block GEMM
            # runs (same frames/order/bits as the monolithic form)
            mixs = field.matmul(pmat_all[s * n_loc:(s + 1) * n_loc],
                                cl.reshape(t_, -1))
            return field.add(mixs.reshape(n_loc, n_loc, dw), f_flat[None])

        with clock("exchange"):
            for s in range(P):
                if s == rank:
                    continue
                node.send(s, net.SHARE, step=step,
                          payload=wire.share_payload(mine_block(s)),
                          phase="exchange")
            blocks = {rank: mine_block(rank)}
            sub = collect_blocks(blocks, step)
        if sub not in dvec_cache:
            dvec_cache[sub] = jnp.asarray(proto._decode_vec(sub))
        dvt = dvec_cache[sub]
        evals = jnp.stack(
            [blocks[g // n_loc][:, g - (g // n_loc) * n_loc, :]
             for g in sub], axis=1)                       # (n_loc, R, dw)
        xtg = jax.vmap(lambda e: field.matmul(dvt[None], e)[0])(evals)
        grad = field.sub(xtg.reshape((n_loc,) + w_shape), xty_loc)
        scaled = field.mul_scalar(grad, proto.q_eta)
        delta = truncation.trunc_pr_core(
            kt, scaled, proto.k1, proto.k2, share=share_rows,
            open_=lambda c_sh: open_via_coord(c_sh, step))
        return field.sub(w_c, delta)

    for t in range(iters):
        kit = jax.random.fold_in(key, t)
        k1_, k2_ = jax.random.split(kit)
        coded_w = encode_model(k1_, w_loc, t)
        with clock("gradient"):
            f_loc = proto.local_gradient(coded_x, coded_w)   # LOCAL
        w_loc = decode_update(k2_, w_loc, f_loc, t)
        if history:
            with clock("open_model"):
                node.send(net.COORD, net.OPEN, step=t, tag=net.TAG_HIST,
                          payload=wire.share_payload(w_loc),
                          phase="open_model")

    with clock("open_model"):
        node.send(net.COORD, net.RESULT, payload=pickle.dumps({
            "w": wire.share_payload(w_loc[:real_count(rank)]),
            "seconds": dict(clock.seconds),
            "bytes": dict(node.sent_bytes),
            "frames": dict(node.sent_frames),
            "dropped": dict(node.dropped_frames),
            "degraded_steps": degraded,
            "wall_s": time.perf_counter() - t_start,
        }), phase="open_model")


def main(argv=None):
    import sys
    args = sys.argv[1:] if argv is None else argv
    if len(args) != 3:
        raise SystemExit(
            "usage: python -m repro.launch.runtime.worker RANK HOST PORT")
    worker_entry(int(args[0]), args[1], int(args[2]))


if __name__ == "__main__":
    main()
