"""Framed-TCP message node: the transport under the proc engine.

One `Node` per process: an asyncio event loop on a dedicated thread owns
every connection (server + dials); the compute thread talks to it through
thread-safe queues.  Each connection is drained by one sequential task,
so per-link frame order is preserved even under injected latency (the
delay is awaited inside that task -- a slow link serializes, it never
reorders).

Receive semantics (the straggler machinery of the whole runtime):

  * frames land in a per-kind inbox; `recv` filters by (src, step, tag)
    with a timeout/retry policy from NetConfig and raises NodeTimeout
    when the budget is gone -- a peer that never delivers *is* a
    straggler, no schedule required;
  * frames for FUTURE steps are buffered until their step comes up;
  * frames for PAST steps (a slow peer's late gradient block) are
    dropped on sight -- exactly the "ignore stale contributions"
    behavior of the paper's elastic decode;
  * an ERR frame from a peer aborts every pending recv (PeerFailure).

Every send is counted into `sent_bytes`/`sent_frames` by protocol phase;
the coordinator sums these across processes into
TrainResult.measured_comm.
"""

from __future__ import annotations

import asyncio
import collections
import json
import queue
import threading
import time

from . import wire
from .config import NetConfig

#: rank of the coordinator on the wire (fits the u16 src header field)
COORD = 0xFFFF

# frame kinds (wire header `kind`)
HELLO = 1     # connection handshake: registers the sender's rank
LISTEN = 2    # worker -> coord: my server address
SESSION = 3   # coord -> worker: config + state rows + address book
READY = 4     # worker -> coord: mesh connected
START = 5     # coord -> worker: barrier release, training begins
ENC = 6       # model-encode reduce-scatter partial rows
SHARE = 7     # gradient-share all-to-all block
OPEN = 8      # worker -> coord: share rows of a value to open
OPENED = 9    # coord -> worker: the reconstructed public value
RESULT = 10   # worker -> coord: final model share rows + stats
BYE = 11      # coord -> worker: result received, shut down
ERR = 12      # worker -> coord (or broadcast): fatal error report

KIND_NAMES = {HELLO: "HELLO", LISTEN: "LISTEN", SESSION: "SESSION",
              READY: "READY", START: "START", ENC: "ENC", SHARE: "SHARE",
              OPEN: "OPEN", OPENED: "OPENED", RESULT: "RESULT",
              BYE: "BYE", ERR: "ERR"}

# `tag` sub-channels of OPEN/OPENED
TAG_TRUNC = 0   # TruncPr's masked opening (every step)
TAG_HIST = 1    # per-step model opening (history runs)


class NodeTimeout(RuntimeError):
    """recv() exhausted its timeout x retries budget."""


class PeerFailure(RuntimeError):
    """A peer reported a fatal error or died mid-session."""


class Node:
    """One process's endpoint: server, dialed links, inboxes, counters."""

    def __init__(self, rank: int, cfg: NetConfig | None = None):
        self.rank = rank
        self.cfg = cfg or NetConfig()
        self.port = None
        self.sent_bytes: dict = {}
        self.sent_frames: dict = {}
        #: stale frames discarded by the drop-past-steps rule, keyed by
        #: kind name.  Receiver-side accounting only: the sender already
        #: counted these under sent_frames, so the per-phase sent totals
        #: stay timing-invariant (and equal to the static budget) no
        #: matter how many late frames get dropped here.
        self.dropped_frames: dict = {}
        #: optional out-of-band liveness probe, called between recv
        #: retries (the coordinator checks worker exit codes here)
        self.liveness = None
        self._loop = None
        self._thread = None
        self._server = None
        self._writers: dict = {}
        self._tasks: list = []
        self._inbox: dict = {}          # kind -> queue.Queue[Frame]
        self._pending: dict = {}        # kind -> deque[Frame]
        self._errors: list = []         # ERR frames / disconnect reports
        self._lock = threading.Lock()

    # ------------------------------------------------------------ lifecycle

    def start(self, listen: bool = True):
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(
            target=self._loop.run_forever, daemon=True,
            name=f"node-{self.rank}")
        self._thread.start()
        if listen:
            fut = asyncio.run_coroutine_threadsafe(
                self._start_server(), self._loop)
            self.port = fut.result(timeout=10.0)
        return self

    def stop(self):
        if self._loop is None:
            return

        async def _shutdown():
            for w in list(self._writers.values()):
                try:
                    w.close()
                except Exception:  # noqa: BLE001 -- teardown best-effort
                    pass
            if self._server is not None:
                self._server.close()
            me = asyncio.current_task()
            for t in asyncio.all_tasks(self._loop):
                if t is not me:
                    t.cancel()
            await asyncio.sleep(0)      # let cancellations land
            self._loop.stop()

        asyncio.run_coroutine_threadsafe(_shutdown(), self._loop)
        self._thread.join(timeout=5.0)

    def configure(self, cfg: NetConfig):
        """Adopt the session NetConfig (workers learn it via SESSION)."""
        self.cfg = cfg

    # ----------------------------------------------------------- event loop

    async def _start_server(self):
        self._server = await asyncio.start_server(
            self._accept, host=self.cfg.host, port=0)
        return self._server.sockets[0].getsockname()[1]

    async def _accept(self, reader, writer):
        await self._pump(reader, writer, peer=None)

    async def _pump(self, reader, writer, peer):
        """Drain one connection sequentially: parse, delay, dispatch."""
        fr = wire.FrameReader()
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    fr.close()
                    break
                for frame in fr.feed(data):
                    if peer is None and frame.kind == HELLO:
                        peer = frame.src
                        self._writers[peer] = writer
                        continue
                    delay = self.cfg.delay(frame.src, self.rank,
                                           len(frame.payload))
                    if delay > 0:
                        await asyncio.sleep(delay)
                    self._dispatch(frame)
        except (asyncio.CancelledError, ConnectionError, OSError):
            pass
        except wire.WireError as e:
            self._errors.append(f"link from {peer}: {e}")
        finally:
            if peer is not None:
                self._writers.pop(peer, None)

    def _dispatch(self, frame):
        if frame.kind == ERR:
            # ERR payloads are UTF-8 JSON ({"rank": int, "error": str});
            # fall back to the raw text so a malformed report still
            # surfaces instead of masking the original failure.
            text = frame.payload.decode("utf-8", "replace")
            try:
                text = json.loads(text)["error"]
            except (ValueError, TypeError, KeyError):
                pass
            self._errors.append(f"peer {frame.src} failed: {text}")
            return
        self._queue(frame.kind).put(frame)

    def _queue(self, kind):
        with self._lock:
            if kind not in self._inbox:
                self._inbox[kind] = queue.Queue()
                self._pending[kind] = collections.deque()
            return self._inbox[kind]

    # ----------------------------------------------------------------- send

    def connect(self, dst: int, host: str, port: int):
        """Dial a peer, retrying until NetConfig.connect_timeout_s."""
        timeout = self.cfg.connect_timeout_s
        fut = asyncio.run_coroutine_threadsafe(
            self._connect(dst, host, port, timeout), self._loop)
        fut.result(timeout=timeout + 5.0)

    async def _connect(self, dst, host, port, timeout):
        deadline = time.monotonic() + timeout
        while True:
            try:
                reader, writer = await asyncio.open_connection(host, port)
                break
            except OSError:
                if time.monotonic() >= deadline:
                    raise
                await asyncio.sleep(0.05)
        self._writers[dst] = writer
        hello = wire.encode_frame(HELLO, self.rank, 0, 0)
        self._count("setup", len(hello))
        writer.write(hello)
        self._tasks.append(
            self._loop.create_task(self._pump(reader, writer, dst)))

    def send(self, dst: int, kind: int, step: int = 0, tag: int = 0,
             payload: bytes = b"", phase: str = "setup"):
        """Queue one frame for dst; counted under `phase`, never blocks."""
        data = wire.encode_frame(kind, self.rank, tag, step, payload)
        self._count(phase, len(data))
        self._loop.call_soon_threadsafe(self._write, dst, data)

    def _count(self, phase, nbytes):
        self.sent_bytes[phase] = self.sent_bytes.get(phase, 0) + nbytes
        self.sent_frames[phase] = self.sent_frames.get(phase, 0) + 1

    def _write(self, dst, data):
        w = self._writers.get(dst)
        if w is None or w.is_closing():
            self._errors.append(f"no live link to peer {dst}")
            return
        w.write(data)

    # ----------------------------------------------------------------- recv

    def recv(self, kind: int, src: int | None = None,
             step: int | None = None, tag: int | None = None,
             timeout: float | None = None,
             retries: int | None = None) -> wire.Frame:
        """Blocking filtered receive with the NetConfig timeout policy."""
        timeout = self.cfg.recv_timeout_s if timeout is None else timeout
        retries = self.cfg.recv_retries if retries is None else retries

        def match(f):
            return ((src is None or f.src == src)
                    and (step is None or f.step == step)
                    and (tag is None or f.tag == tag))

        for _ in range(max(1, retries)):
            frame = self._wait(kind, match, timeout, drop_below=step)
            if frame is not None:
                return frame
            if self.liveness is not None:
                self.liveness()
        raise NodeTimeout(
            f"rank {self.rank}: no {KIND_NAMES.get(kind, kind)} frame "
            f"(src={src}, step={step}, tag={tag}) after "
            f"{max(1, retries)} x {timeout}s")

    def recv_any(self, kind: int, step: int,
                 timeout: float) -> wire.Frame | None:
        """First `kind` frame at exactly `step` from ANY peer, else None
        after `timeout` -- the decode phase's straggler-tolerant wait."""
        return self._wait(kind, lambda f: f.step == step, timeout,
                          drop_below=step)

    def _wait(self, kind, match, timeout, drop_below=None):
        q = self._queue(kind)
        pend = self._pending[kind]
        deadline = time.monotonic() + timeout
        while True:
            if drop_below is not None:
                for i in range(len(pend) - 1, -1, -1):
                    if pend[i].step < drop_below:
                        self._drop(kind)
                        del pend[i]
            for i, f in enumerate(pend):
                if match(f):
                    del pend[i]
                    return f
            self._raise_errors()
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return None
            try:
                f = q.get(timeout=min(0.05, remaining))
            except queue.Empty:
                continue
            if match(f):
                return f
            if drop_below is not None and f.step < drop_below:
                self._drop(kind)              # stale: a passed step's frame
                continue
            pend.append(f)

    def _drop(self, kind):
        name = KIND_NAMES.get(kind, str(kind))
        self.dropped_frames[name] = self.dropped_frames.get(name, 0) + 1

    def _raise_errors(self):
        if self._errors:
            raise PeerFailure("; ".join(str(e) for e in self._errors))

    # ---------------------------------------------------------------- stats

    def stats(self) -> dict:
        return {"bytes": dict(self.sent_bytes),
                "frames": dict(self.sent_frames),
                "dropped": dict(self.dropped_frames)}
