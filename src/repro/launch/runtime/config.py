"""NetConfig: every network knob of the multi-process runtime.

Kept dependency-free (no jax, no asyncio) so api/engine.py can import it
without touching the runtime's heavy modules.  An instance is frozen and
picklable: the coordinator embeds it in the SESSION blob, so every worker
applies the same link model.

Latency/bandwidth are injected at the RECEIVER when a frame is taken off
the wire: each connection is drained by one sequential task, so delayed
frames stay ordered per link (a slow link serializes, it never reorders).
Straggling then *emerges* from timing -- a worker whose frames arrive
late simply misses the decode deadline and the survivors decode without
it (LCC decode invariance keeps the result bit-exact, see
docs/ARCHITECTURE.md).
"""

from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class NetConfig:
    """Link model + timeout policy for one proc-engine session.

    host             interface to bind/dial (default loopback).
    latency_s        default one-way per-frame delay on every link.
    bandwidth_bps    optional link bandwidth; adds len(frame)/bandwidth
                     of serialization delay per frame (None = infinite).
    links            per-link latency overrides, most specific match wins:
                     ((src, dst, seconds), ...) where src/dst are ranks or
                     None for "any" -- (3, None, 0.35) makes every frame
                     FROM rank 3 arrive 0.35s late anywhere.
    recv_timeout_s   how long one recv() wait lasts before a retry.
    recv_retries     retries per recv() before NodeTimeout.
    connect_timeout_s  dial/handshake budget per connection.
    spawn_timeout_s  coordinator's budget for worker HELLOs (process
                     spawn + jax import happen inside it).
    decode_timeout_s gradient-decode straggler deadline: once >= R real
                     owners' blocks arrived, wait at most this long for
                     the rest before decoding from the survivors.  None =
                     wait for everyone (the recv timeout still degrades
                     to the survivors if >= R are in hand).
    """
    host: str = "127.0.0.1"
    latency_s: float = 0.0
    bandwidth_bps: float | None = None
    links: tuple = ()
    recv_timeout_s: float = 30.0
    recv_retries: int = 3
    connect_timeout_s: float = 30.0
    spawn_timeout_s: float = 180.0
    decode_timeout_s: float | None = None

    def link_latency(self, src: int, dst: int) -> float:
        """One-way latency for src->dst frames (most specific link wins)."""
        best, best_score = self.latency_s, -1
        for entry in self.links:
            s, d, lat = entry
            if (s is None or s == src) and (d is None or d == dst):
                score = (s is not None) * 2 + (d is not None)
                if score > best_score:
                    best, best_score = float(lat), score
        return best

    def delay(self, src: int, dst: int, nbytes: int) -> float:
        """Total injected delivery delay for one frame on src->dst."""
        d = self.link_latency(src, dst)
        if self.bandwidth_bps:
            d += nbytes / float(self.bandwidth_bps)
        return d

    @classmethod
    def from_env(cls) -> "NetConfig":
        """Defaults, overridable per process via REPRO_PROC_* variables
        (documented in docs/RUNNING.md): REPRO_PROC_HOST,
        REPRO_PROC_LATENCY_S, REPRO_PROC_TIMEOUT_S, REPRO_PROC_RETRIES."""
        return cls(
            host=os.environ.get("REPRO_PROC_HOST", "127.0.0.1"),
            latency_s=float(os.environ.get("REPRO_PROC_LATENCY_S", "0")),
            recv_timeout_s=float(
                os.environ.get("REPRO_PROC_TIMEOUT_S", "30")),
            recv_retries=int(os.environ.get("REPRO_PROC_RETRIES", "3")),
        )
