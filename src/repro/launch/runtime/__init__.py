"""Multi-process COPML runtime: clients as OS processes over TCP.

The `proc:N` engine (api.fit(..., engine="proc:4")): each worker process
owns a contiguous client group and exchanges framed share/coded payloads
over real localhost sockets; a coordinator process handles session setup
and the opening barrier rounds.  See docs/RUNNING.md "Multi-process" and
docs/ARCHITECTURE.md for the wire format and the measured-vs-modeled
communication record.

    wire      length-prefixed frame format + array payloads
    net       async framed-TCP Node (latency injection, timeout/retry)
    config    NetConfig: every network knob, env-overridable
    worker    per-process client-group compute + socket collectives
    session   coordinator: run_copml_proc, the engine entry point
"""

from .config import NetConfig
from .session import DEFAULT_PROCS, run_copml_proc

__all__ = ["NetConfig", "DEFAULT_PROCS", "run_copml_proc"]
