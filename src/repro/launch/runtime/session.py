"""Coordinator: spawn workers, deal state, drive open rounds, assemble.

The parent process runs the one-time setup (Phases 1-2, identical to the
jit engine: same key split, same dealer draws), deals each worker its
padded client rows over the SESSION frame, then acts as the opening
barrier of the training loop: per step it gathers every rank's TruncPr
share rows, reconstructs, and broadcasts the public value back (plus the
per-step model opening on history runs).  Afterwards it reassembles the
final CopmlState from the workers' model share rows -- so the state the
caller sees is byte-identical to the in-process engines' -- and merges
every node's byte/time counters into the measured_comm record.

This is the `proc:N` engine behind api.fit; see docs/RUNNING.md
"Multi-process" for the knobs.
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from ...core import quantize, shamir
from ...core.protocol import _pad_clients
from . import net, wire
from .config import NetConfig

#: processes a bare "proc" engine spec launches (capped at N clients)
DEFAULT_PROCS = 4


def run_copml_proc(proto, key, client_xs, client_ys, iters: int, *,
                   procs: int | None = None, net_cfg: NetConfig | None = None,
                   subset=None, history: bool = False) -> tuple:
    """Train `proto` over P OS processes on real localhost sockets.

    Returns (state, weights, history-or-None, measured_comm) with
    state/weights/history bit-exact to the jit engine (the conformance
    suite in tests/test_runtime_engine.py pins this against the goldens).
    """
    cfg = proto.cfg
    n = cfg.n_clients
    P = DEFAULT_PROCS if procs is None else int(procs)
    P = min(P, n)
    if P < 1:
        raise ValueError(f"proc engine needs >= 1 process, got {P}")
    ncfg = NetConfig.from_env() if net_cfg is None else net_cfg
    iters = int(iters)
    subset = None if subset is None else tuple(subset)

    t0 = time.perf_counter()
    ks, ki = jax.random.split(key)
    state = proto.setup(ks, client_xs, client_ys)   # one-time, in-process
    n_loc = -(-n // P)
    n_pad = n_loc * P
    w_pad = _pad_clients(state.w_shares, n_pad)
    cx_pad = _pad_clients(state.coded_x, n_pad)
    xty_pad = _pad_clients(state.xty_shares, n_pad)

    node = net.Node(net.COORD, cfg=ncfg).start()
    # Plain subprocesses (NOT multiprocessing spawn): each worker is
    # `python -m repro.launch.runtime.worker RANK HOST PORT`, so nothing
    # of the caller's __main__ is re-imported and each client really is
    # an independent OS process with its own fresh jax runtime.
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [p for p in sys.path if p]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    workers = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.runtime.worker",
         str(r), ncfg.host, str(node.port)], env=env)
        for r in range(P)]

    def check_workers():
        dead = [r for r, p in enumerate(workers)
                if p.poll() not in (None, 0)]
        if dead:
            raise net.PeerFailure(
                f"worker process(es) {dead} exited "
                f"(exit codes {[workers[r].poll() for r in dead]}); "
                f"see their stderr for the traceback")

    node.liveness = check_workers
    try:
        addrs = {}
        for _ in range(P):
            frm = node.recv(net.LISTEN, timeout=ncfg.spawn_timeout_s)
            info = pickle.loads(frm.payload)
            addrs[frm.src] = (info["host"], info["port"])
        base = dict(cfg=cfg, m=proto.m, d=proto.d, objective=proto.obj,
                    key=np.asarray(ki), iters=iters, n_procs=P, net=ncfg,
                    subset=subset, history=bool(history), addrs=addrs)
        for r in range(P):
            rows = slice(r * n_loc, (r + 1) * n_loc)
            node.send(r, net.SESSION, payload=pickle.dumps(dict(
                base, rank=r,
                w_rows=wire.share_payload(w_pad[rows]),
                coded_rows=wire.share_payload(cx_pad[rows]),
                xty_rows=wire.share_payload(xty_pad[rows]))))
        for r in range(P):
            node.recv(net.READY, src=r, timeout=ncfg.spawn_timeout_s)
        setup_wall = time.perf_counter() - t0
        for r in range(P):
            node.send(r, net.START)

        hist_rows = [] if history else None
        for t in range(iters):
            c_full = _gather_rows(node, P, t, net.TAG_TRUNC)[:n]
            c = shamir.reconstruct(c_full, cfg.t, proto.lambdas)
            opened = wire.pack_array(np.asarray(c))
            for r in range(P):
                node.send(r, net.OPENED, step=t, tag=net.TAG_TRUNC,
                          payload=opened, phase="trunc_open")
            if history:
                w_full = _gather_rows(node, P, t, net.TAG_HIST)[:n]
                wf = shamir.reconstruct(w_full, cfg.t, proto.lambdas)
                hist_rows.append(
                    np.asarray(quantize.dequantize(wf, cfg.lw)))

        results = {}
        result_wire = 0
        for r in range(P):
            frm = node.recv(net.RESULT, src=r)
            # the RESULT payload carries the worker's own send counters,
            # so the worker cannot count this frame itself (fixed point);
            # the coordinator meters the exact bytes it received instead.
            result_wire += wire.HEADER_SIZE + len(frm.payload)
            results[r] = pickle.loads(frm.payload)
            node.send(r, net.BYE)
        w_shares = jnp.concatenate(
            [jnp.asarray(wire.unpack_array(results[r]["w"]))
             for r in range(P)], axis=0)
        state = dataclasses.replace(
            state, w_shares=w_shares,
            step=state.step + jnp.asarray(iters, jnp.int32))
        w = proto.open_model(state)
        for p in workers:
            try:
                p.wait(timeout=10.0)
            except subprocess.TimeoutExpired:
                pass
        hist = None
        if history:
            hist = np.stack(hist_rows) if hist_rows else \
                np.zeros((0,) + proto.w_shape, np.float32)
        measured = _assemble_measured(results, node, P, iters,
                                      time.perf_counter() - t0, setup_wall,
                                      result_wire)
        return state, w, hist, measured
    finally:
        node.stop()
        for p in workers:
            if p.poll() is None:
                p.terminate()
            try:
                p.wait(timeout=5.0)
            except subprocess.TimeoutExpired:
                p.kill()


def _gather_rows(node, P: int, step: int, tag: int):
    """Stack every rank's (n_loc,)+shape OPEN rows into (n_pad,)+shape."""
    rows = [jnp.asarray(wire.unpack_array(
        node.recv(net.OPEN, src=r, step=step, tag=tag).payload))
        for r in range(P)]
    return jnp.concatenate(rows, axis=0)


def _assemble_measured(results, node, P, iters, wall, setup_wall,
                       result_wire) -> dict:
    """Merge per-node counters: bytes sum over every process (each frame
    is sent exactly once), per-phase seconds take the max over workers
    (the slowest rank is the step's critical path).  `result_wire` is the
    coordinator-metered size of the P RESULT frames, which the workers
    cannot self-count."""
    bytes_by_phase = dict(node.sent_bytes)
    frames_by_phase = dict(node.sent_frames)
    bytes_by_phase["open_model"] = (bytes_by_phase.get("open_model", 0)
                                    + result_wire)
    frames_by_phase["open_model"] = (frames_by_phase.get("open_model", 0)
                                     + P)
    # receiver-side stale-drop counts sum across every process; they are
    # deliberately NOT part of frames_by_phase, which counts sends only
    # and therefore matches the static choreography budget exactly even
    # on degraded runs (a dropped frame was still sent).
    dropped_frames = dict(node.dropped_frames)
    seconds_by_phase: dict = {}
    degraded = 0
    for res in results.values():
        for k, v in res["bytes"].items():
            bytes_by_phase[k] = bytes_by_phase.get(k, 0) + v
        for k, v in res["frames"].items():
            frames_by_phase[k] = frames_by_phase.get(k, 0) + v
        for k, v in res.get("dropped", {}).items():
            dropped_frames[k] = dropped_frames.get(k, 0) + v
        for k, v in res["seconds"].items():
            seconds_by_phase[k] = max(seconds_by_phase.get(k, 0.0), v)
        degraded = max(degraded, res["degraded_steps"])
    return {
        "engine": f"proc:{P}",
        "procs": P,
        "iters": iters,
        "bytes_by_phase": bytes_by_phase,
        "total_bytes": sum(bytes_by_phase.values()),
        "frames_by_phase": frames_by_phase,
        "dropped_frames": dropped_frames,
        "seconds_by_phase": seconds_by_phase,
        "degraded_steps": degraded,
        "setup_wall_s": setup_wall,
        "wall_s": wall,
    }
