"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

HLO_FLOPs / HLO_bytes come from compiled.cost_analysis() (global totals).
collective_bytes is parsed from the post-SPMD HLO: for each all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take the
per-device result-shape bytes (post-SPMD shapes are per-shard), apply a
ring-model factor, and multiply by chips to get the global count the
formula above divides back down.

v5e constants: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(?:\(([^)]*)\)|((?:\w+)\[[^\]]*\][^ ]*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

# ring-model bytes-on-wire per device, as a multiple of the RESULT bytes
_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(txt: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes on the wire, by collective kind + total."""
    out = {k: 0.0 for k in _FACTORS}
    counts = {k: 0 for k in _FACTORS}
    for m in _COLL_RE.finditer(hlo_text):
        kind = m.group(4)
        shapes_txt = m.group(2) or m.group(3) or ""
        b = _shape_bytes(shapes_txt)
        out[kind] += b * _FACTORS[kind]
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _FACTORS)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    name: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes_per_device: float
    model_flops: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def collective_s(self) -> float:
        # per-device bytes / per-chip ICI bw == global/(chips*bw)
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """How close the *useful* work runs to the binding roofline term:
        (MODEL_FLOPS / peak) / bound_s."""
        if not self.model_flops or not self.bound_s:
            return 0.0
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.bound_s

    def to_dict(self) -> dict:
        return {
            "name": self.name, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for training (N_active for MoE); decode/prefill
    use 2*N*tokens (forward only) + attention KV term."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence + KV attention reads
    tokens = shape.global_batch
    attn = 0.0
    if cfg.n_heads:
        attn = (4.0 * cfg.n_layers * cfg.n_heads * cfg.hd * shape.seq_len
                * tokens)
    return 2.0 * n_active * tokens + attn


def analyze(name, compiled, chips: int, mflops: float) -> Roofline:
    """Loop-aware counts from the post-SPMD HLO (hlo_counter.py).

    XLA's cost_analysis() counts while bodies once -- useless under
    scan-over-layers -- so we parse and loop-correct the HLO ourselves.
    Parsed counts are per-device; we scale to global so the roofline
    formulas (global / (chips * peak)) read naturally.
    """
    from . import hlo_counter
    c = hlo_counter.analyze_hlo(compiled.as_text())
    return Roofline(name=name, chips=chips,
                    hlo_flops=c.flops * chips,
                    hlo_bytes=c.bytes * chips,
                    coll_bytes_per_device=c.coll_bytes,
                    model_flops=mflops)
