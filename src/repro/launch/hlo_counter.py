"""Loop-aware cost counting over post-SPMD HLO text.

XLA's compiled.cost_analysis() counts a while-loop body ONCE, which makes it
useless for scan-over-layers programs (a 64-layer model reports ~1/64 of its
FLOPs).  This module re-derives the three roofline inputs from the HLO text
itself, propagating loop trip counts through the call graph:

  * FLOPs: dot ops (2 * prod(out) * prod(contracting)) + arithmetic
    elementwise ops (prod(out) each) -- SSM scans are elementwise-dominated,
    so elementwise counting matters.
  * HBM bytes: operand+result bytes at fusion boundaries (fusion, dot, copy,
    and other non-trivial top-level ops).  Approximates traffic assuming
    fused intermediates stay in registers/VMEM.
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, with ring-model
    factors, multiplied by enclosing trip counts.

Trip counts come from each while's condition computation (compare of the
induction variable with a constant).  Every count is an approximation of the
true executed program, but unlike cost_analysis() it is loop-correct, which
is what the roofline needs.
"""

from __future__ import annotations

import dataclasses
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_ARITH = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "rsqrt", "sqrt",
    "tanh", "logistic", "cosine", "sine", "maximum", "minimum", "abs",
    "negate", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "atan2", "remainder", "select", "compare", "clamp", "reduce",
    "convert", "erf", "cbrt",
}

_COLL_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                 "all-to-all": 1.0, "collective-permute": 1.0}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\](?:\{[\d,]*\})?")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*[^{]+{\s*$")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|\S+)\s+([\w\-]+)\(")
_CALL_RE = re.compile(
    r"(?:calls=|to_apply=|body=|condition=|branch_computations=\{)"
    r"%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)")


def _shape_elems_bytes(txt: str):
    elems = bts = 0
    for m in _SHAPE_RE.finditer(txt):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_FACTORS})
    coll_ops: dict = dataclasses.field(
        default_factory=lambda: {k: 0 for k in _COLL_FACTORS})

    def add(self, other: "Counts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k in _COLL_FACTORS:
            self.coll_by_kind[k] += other.coll_by_kind[k] * mult
            self.coll_ops[k] += other.coll_ops[k] * mult


def _parse_computations(text: str) -> dict:
    comps, name, lines = {}, None, []
    for raw in text.splitlines():
        if name is None:
            m = _COMP_HDR.match(raw.strip()) if "{" in raw else None
            if m and "->" in raw:
                name = m.group(1)
                lines = []
                if raw.strip().startswith("ENTRY"):
                    comps["__entry__"] = name
        else:
            if raw.strip() == "}":
                comps[name] = lines
                name = None
            else:
                lines.append(raw)
    return comps


_OPERANDS_RE = re.compile(r"\(([^)]*)\)")


def _dot_flops(line: str, symtab: dict) -> float:
    """2 * prod(out) * prod(contracting dims of lhs).

    Operand shapes are not inline in this HLO dialect; `symtab` maps op
    names to their result-shape strings within the computation."""
    head, _, tail = line.partition(" dot(")
    out_e, _ = _shape_elems_bytes(head.split("=", 1)[1])
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
    cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
    contract = 1
    lhs_shape = None
    ops_m = _OPERANDS_RE.search(" dot(" + tail)
    if ops_m:
        first = ops_m.group(1).split(",")[0].strip().lstrip("%")
        lhs_shape = symtab.get(first)
    if lhs_shape is None:                      # shape inline (older dialect)
        sm = _SHAPE_RE.search(tail)
        lhs_shape = sm.group(0) if sm else None
    if lhs_shape:
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(x) for x in sm.group(2).split(",") if x]
            for c in cdims:
                if c < len(dims):
                    contract *= dims[c]
    return 2.0 * out_e * contract


def _symtab(lines) -> dict:
    """Map op name -> result shape string within one computation."""
    tab = {}
    for ln in lines:
        m = _OP_RE.match(ln)
        if m:
            tab[m.group(1)] = m.group(2)
    return tab


def _trip_count(cond_lines, comps=None) -> float:
    """Max integer constant in the while condition (scan trip count).

    XLA CPU often fuses the compare into a called computation, so we follow
    calls= / to_apply= references one level deep."""
    consts = [0]
    frontier = list(cond_lines)
    seen = set()
    for _ in range(2):                       # condition + its callees
        called = []
        for ln in frontier:
            for m in re.finditer(r"constant\((\d+)\)", ln):
                consts.append(int(m.group(1)))
            if comps is not None:
                cm = _CALL_RE.search(ln)
                if cm:
                    for callee in re.split(r",\s*%?", cm.group(1)):
                        if callee not in seen:
                            seen.add(callee)
                            called.extend(comps.get(callee, []))
        frontier = called
    return float(max(consts)) if max(consts) > 0 else 1.0


@lru_cache(maxsize=32)
def _analyze_text(text: str) -> Counts:
    comps = _parse_computations(text)
    entry = comps.pop("__entry__", None)
    memo: dict = {}

    def comp_counts(name: str, stack=()) -> Counts:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Counts()
        total = Counts()
        symtab = _symtab(comps[name])
        for ln in comps[name]:
            m = _OP_RE.match(ln)
            if not m:
                continue
            _, out_sig, opcode = m.groups()
            out_e, out_b = _shape_elems_bytes(out_sig)
            if opcode == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                trip = _trip_count(comps.get(cm.group(1), []), comps) \
                    if cm else 1.0
                if bm:
                    total.add(comp_counts(bm.group(1), stack + (name,)), trip)
                # loop state bytes are NOT added here: each iteration reads
                # only its xs slice + carry, which the body's own fusion/dot
                # boundary traffic already captures
                total.bytes += out_b
            elif opcode in ("fusion", "call", "custom-call", "conditional"):
                cm = _CALL_RE.search(ln)
                if cm:
                    for callee in re.split(r",\s*%?", cm.group(1)):
                        total.add(comp_counts(callee, stack + (name,)))
                # fusion boundary traffic: result + operands (via symtab)
                total.bytes += out_b
                om = _OPERANDS_RE.search(ln)
                if om:
                    for nm in om.group(1).split(","):
                        _, ob = _shape_elems_bytes(
                            symtab.get(nm.strip().lstrip("%"), ""))
                        total.bytes += ob
            elif opcode == "dot":
                total.flops += _dot_flops(ln, symtab)
                _, out_b2 = _shape_elems_bytes(ln)
                total.bytes += out_b2
                # operand bytes via symtab (shapes not inline)
                ops_m = _OPERANDS_RE.search(ln.split(" dot(", 1)[1]
                                            if " dot(" in ln else ln)
                if ops_m:
                    for nm in ops_m.group(1).split(","):
                        _, ob = _shape_elems_bytes(
                            symtab.get(nm.strip().lstrip("%"), ""))
                        total.bytes += ob
            elif opcode in _COLL_FACTORS:
                total.coll_bytes += out_b * _COLL_FACTORS[opcode]
                total.coll_by_kind[opcode] += out_b * _COLL_FACTORS[opcode]
                total.coll_ops[opcode] += 1
                total.bytes += out_b
            elif opcode in _ARITH:
                total.flops += out_e
                # NOT counted as bytes: on the TPU target these fuse into
                # neighbouring ops (CPU-backend HLO under-fuses, and counting
                # them as HBM traffic overstated the memory term ~1000x)
            elif opcode in ("copy", "scatter", "gather",
                            "dynamic-update-slice", "sort", "convolution"):
                # genuine data movement even on TPU
                if "fused" not in name:
                    total.bytes += out_b
        memo[name] = total
        return total

    return comp_counts(entry) if entry else Counts()


def analyze_hlo(text: str) -> Counts:
    return _analyze_text(text)
