"""Training entry point.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 256 --ckpt /tmp/ckpt

Uses the reduced (smoke) config by default on CPU hosts; pass --full for the
assigned production config (sized for the v5e meshes, see launch/dryrun.py).

The paper's own workload is an arch too: `--arch copml-logreg` routes
through the repro.api facade (one front door for every experiment):

    PYTHONPATH=src python -m repro.launch.train --arch copml-logreg \
        --workload quickstart --protocol copml --engine jit
"""

from __future__ import annotations

import argparse

import jax

from ..configs import registry
from ..train import trainer
from . import mesh as mesh_lib


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m",
                    choices=list(registry.ARCH_IDS))
    # copml-logreg only: the (workload, protocol, engine) run triple
    ap.add_argument("--workload", default="quickstart")
    ap.add_argument("--protocol", default="copml")
    ap.add_argument("--engine", default="jit")
    ap.add_argument("--iters", type=int, default=None,
                    help="copml-logreg GD iterations (default: workload's)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)

    if args.arch == "copml-logreg":
        from .. import api
        res = api.fit(args.workload, args.protocol, args.engine,
                      iters=args.iters)
        print(res.summary())
        return

    cfg = (registry.get_config(args.arch) if args.full
           else registry.smoke_config(args.arch))
    tcfg = trainer.TrainConfig(
        steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        microbatch=args.microbatch, loss_chunk=args.loss_chunk,
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every)
    mesh = mesh_lib.make_host_mesh(args.model_parallel) \
        if len(jax.devices()) > 1 else None
    params, history = trainer.train(cfg, tcfg, mesh=mesh)
    print(f"final loss: {history[-1]['loss']:.4f} "
          f"({cfg.name}, {args.steps} steps)")


if __name__ == "__main__":
    main()
