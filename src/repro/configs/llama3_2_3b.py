"""llama3.2-3b [dense] -- small llama3.  [hf:meta-llama/Llama-3.2-1B; unverified]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv=8, d_ff=8192,
    vocab=128256, rope_theta=5e5,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=96, n_heads=6, n_kv=2, d_ff=192,
                      vocab=256)
