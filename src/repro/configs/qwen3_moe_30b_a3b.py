"""qwen3-moe-30b-a3b [moe] -- 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B; hf]"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_ff=768,
    vocab=151936, head_dim=64, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=32,
                      vocab=256, head_dim=16, n_experts=8, top_k=2)
