"""falcon-mamba-7b [ssm] -- mamba1, attention-free.  [arXiv:2410.05355; unverified]

Sub-quadratic: runs long_500k (O(1)-state decode)."""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv=0, d_ff=0,
    vocab=65024, ssm_state=16, ssm_version=1, subquadratic=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, vocab=256, ssm_state=4)
