"""Config registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib

from ..models.config import ModelConfig

ARCH_IDS = (
    "qwen3-1.7b",
    "qwen2.5-3b",
    "smollm-360m",
    "llama3.2-3b",
    "falcon-mamba-7b",
    "qwen3-moe-30b-a3b",
    "arctic-480b",
    "whisper-tiny",
    "zamba2-2.7b",
    "internvl2-2b",
    "copml-logreg",        # the paper's own workload, as an arch
)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(
        f".{arch.replace('-', '_').replace('.', '_')}", __package__)
    return mod.CONFIG


def smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(
        f".{arch.replace('-', '_').replace('.', '_')}", __package__)
    return mod.SMOKE
