"""arctic-480b [moe] -- 128 experts top-2 + dense residual branch.
[hf:Snowflake/snowflake-arctic-base; hf]

AdamW's unfactored f32 states do not fit v5e HBM at this size on a 256-chip
pod; the config selects Adafactor (factored second moment) -- see DESIGN.md.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv=8, d_ff=4864,
    vocab=32000, head_dim=128,
    n_experts=128, top_k=2, dense_residual=True,
    optimizer="adafactor",
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=48,
                      vocab=256, head_dim=16, n_experts=8, top_k=2)
