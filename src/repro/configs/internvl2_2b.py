"""internvl2-2b [vlm] -- InternViT + InternLM2 backbone.  [arXiv:2404.16821; hf]

The ViT frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings (B, n_patches, d_model) which the LM consumes
as a prefix; the transformer backbone below is the InternLM2-side config.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv=8, d_ff=8192,
    vocab=92553, n_patches=1024,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv=2, d_ff=128,
                      vocab=256, n_patches=16)
