"""zamba2-2.7b [hybrid] -- Mamba2 backbone + SHARED attention block every 6
layers (weight sharing is the zamba2 design).  [arXiv:2411.15242; hf]

Sub-quadratic: runs long_500k; at 500k context the shared attention block
uses a 4096-token sliding window (documented adaptation, DESIGN.md section 6)
while the Mamba2 path carries unbounded-range state.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv=32, d_ff=10240,
    vocab=32000, ssm_state=64, ssm_version=2, attn_every=6,
    subquadratic=True, window=4096,
)

SMOKE = CONFIG.scaled(n_layers=4, d_model=64, n_heads=4, n_kv=4, d_ff=128,
                      vocab=256, ssm_state=8, attn_every=2, window=None)
