"""whisper-tiny [audio] -- enc-dec, conv frontend STUB.  [arXiv:2212.04356; unverified]

The modality frontend is a stub per the assignment: input_specs() provides
precomputed frame embeddings (B, encoder_seq, d_model) in place of the
log-mel + conv stem.  Decoder shapes follow the assigned LM shape set.
"""

from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv=6, d_ff=1536,
    vocab=51865, encoder_layers=4, encoder_seq=1500,
)

SMOKE = CONFIG.scaled(n_layers=2, encoder_layers=2, d_model=48, n_heads=3,
                      n_kv=3, d_ff=96, vocab=256, encoder_seq=64)
