"""The paper's own workload as a selectable arch: COPML secure logistic
regression.  Shapes mirror the paper's datasets (Section V-A):

  cifar10  : (m, d) = (9019, 3073)
  gisette  : (m, d) = (6000, 5000)
  scaled   : a 64x larger synthetic workload exercising pod-scale K/T

Not a ModelConfig -- the COPML protocol has its own config type; the dry-run
and roofline treat it via launch/copml_dist.py.

This module is the source of truth for the PAPER-SCALE shapes only; the
runnable workload registry (these entries plus reduced-scale ones with
eval splits, data builders attached) lives in repro.api.workloads and is
what api.fit consumes.
"""

import dataclasses

from ..core.protocol import CopmlConfig


@dataclasses.dataclass(frozen=True)
class CopmlWorkload:
    name: str
    m: int
    d: int
    cfg: CopmlConfig


def _cfg(n, k, t):
    return CopmlConfig(n_clients=n, k=k, t=t, eta=1.0)


# paper-scale (N=50, Case 1 / Case 2 from Section V)
CIFAR10_CASE1 = CopmlWorkload("cifar10_case1", 9019, 3073, _cfg(50, 16, 1))
CIFAR10_CASE2 = CopmlWorkload("cifar10_case2", 9019, 3073, _cfg(50, 10, 7))
GISETTE_CASE1 = CopmlWorkload("gisette_case1", 6000, 5000, _cfg(50, 16, 1))
# pod-scale (N=512 clients = one client per device on the multi-pod mesh)
POD512 = CopmlWorkload("pod512", 262144, 4096, _cfg(512, 128, 43))

WORKLOADS = {w.name: w for w in
             (CIFAR10_CASE1, CIFAR10_CASE2, GISETTE_CASE1, POD512)}

CONFIG = CIFAR10_CASE2     # default
SMOKE = CopmlWorkload("smoke", 96, 12, _cfg(13, 4, 1))
