"""TrainResult: the uniform return value of api.fit.

Every protocol x engine combination produces the same schema, so the
paper-artifact reproductions (Fig. 3/4, Table I/II) become pure
formatting over TrainResult fields.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.baselines import sigmoid


def accuracy_of(w, x, y) -> float:
    """Binary accuracy of a VECTOR model w on (x, y).

    Legacy helper predating the objective layer; fit() itself scores via
    the workload's objective.  Matrix models must go through
    `workload.objective.score` (argmax semantics), so they are rejected
    here instead of broadcasting into a meaningless mean."""
    w = np.asarray(w, np.float64)
    if w.ndim != 1:
        raise ValueError(
            f"accuracy_of scores (d,) vector models; got shape {w.shape} -- "
            f"score matrix models with workload.objective.score(w, x, y)")
    z = np.asarray(x, np.float64) @ w
    return float(((sigmoid(z) > 0.5) == np.asarray(y)).mean())


def accuracy_curve(history, x, y, objective=None) -> np.ndarray:
    """Per-iteration score of the opened model trajectory.

    With `objective` (a core/objectives.SecureObjective) each step is
    scored by `objective.score`, so matrix-model histories work.  Without
    one, only vector-model histories (iters, d) are accepted -- a matrix
    history raises the same named ValueError as `accuracy_of`, but BEFORE
    the loop instead of mid-iteration."""
    hist = np.asarray(history)
    if objective is not None:
        return np.asarray([objective.score(w, x, y) for w in hist])
    if hist.ndim != 2:
        raise ValueError(
            f"accuracy_of scores (d,) vector models; got shape "
            f"{hist.shape[1:]} -- score matrix models with "
            f"workload.objective.score(w, x, y) or pass objective= here")
    return np.asarray([accuracy_of(w, x, y) for w in hist])


@dataclasses.dataclass
class TrainResult:
    """What a fit() returns, protocol- and engine-independent.

    weights        final opened model, float: (d,) for vector objectives,
                   (d, C) for a multi-class one-vs-rest matrix model
    history        opened model after every step, float (iters,) + the
                   model shape, or None when the run was asked not to keep
                   it
    accuracy       per-step eval score (iters,), or None without history;
                   the workload's objective defines the score (binary /
                   argmax accuracy for the logistic objectives, R^2 for
                   linreg)
    final_accuracy score of `weights` on the workload's eval set
    per_class_accuracy
                   (C,) per-class accuracy of `weights` for multi-class
                   objectives (NaN where the eval set has no examples of a
                   class), None for vector objectives
    wall_time_s    end-to-end wall time of the run (setup + train + open;
                   includes compilation on the first fit of a given shape)
    cost           modeled per-client comm/comp/enc seconds on the paper's
                   WAN parameters (core/cost_model), or None for protocols
                   the paper does not price (float, poly_float, secure_agg)
    state          protocol-native final state (e.g. CopmlState with the
                   final secret shares), for tests and further inspection
    availability   per-step availability record of the run's FaultPlan,
                   bool (iters, N) (True = client contributed honestly and
                   on time that step), or None for a fault-free run
    measured_comm  MEASURED (not modeled) communication record of a
                   proc-engine run, None for the in-process engines:
                   bytes_by_phase / frames_by_phase (wire bytes/frames
                   actually sent, summed over every process, keyed by
                   protocol phase: setup, encode, exchange, trunc_open,
                   open_model), total_bytes, seconds_by_phase (per-phase
                   wall time, max over workers = the critical path),
                   degraded_steps (steps where some holder decoded from
                   a strict subset of owners), setup_wall_s, wall_s,
                   procs, iters.  Sits alongside `cost` (the WAN model)
                   for the measured-vs-modeled comparison in
                   docs/ARCHITECTURE.md
    """
    workload: str
    protocol: str
    engine: str
    iters: int
    weights: np.ndarray
    wall_time_s: float
    history: np.ndarray | None = None
    accuracy: np.ndarray | None = None
    final_accuracy: float | None = None
    per_class_accuracy: np.ndarray | None = None
    cost: dict | None = None
    state: object = None
    availability: np.ndarray | None = None
    measured_comm: dict | None = None

    @property
    def triple(self) -> tuple:
        """(workload, protocol, engine): the full run specification."""
        return (self.workload, self.protocol, self.engine)

    def summary(self) -> str:
        parts = [f"{self.workload} x {self.protocol} x {self.engine}:",
                 f"{self.iters} iters in {self.wall_time_s:.2f}s"]
        if self.final_accuracy is not None:
            parts.append(f"accuracy {self.final_accuracy:.3f}")
        if self.per_class_accuracy is not None:
            worst = np.nanmin(self.per_class_accuracy)
            parts.append(f"(worst class {worst:.3f} "
                         f"of {len(self.per_class_accuracy)})")
        if self.cost is not None:
            parts.append(f"modeled total {self.cost['total_s']:.0f}s "
                         f"(comm {self.cost['comm_s']:.0f}s)")
        if self.measured_comm is not None:
            mc = self.measured_comm
            parts.append(f"measured {mc['total_bytes'] / 1e6:.2f}MB "
                         f"over {mc['procs']} procs")
            if mc.get("degraded_steps"):
                parts.append(f"({mc['degraded_steps']} degraded steps)")
        if self.availability is not None:
            n = self.availability.shape[1]
            parts.append(f"churn: min {int(self.availability.sum(1).min())}"
                         f"/{n} clients available")
        return "  ".join(parts)
