"""CLI front door: run any (workload, protocol, engine) triple.

    repro-fit smoke --protocol copml --engine jit          # console script
    PYTHONPATH=src python -m repro.api.cli --list          # registries
    repro-serve smoke --engine jit --queries 64            # train + serve

Prints the TrainResult summary line (and the accuracy curve with -v).
`serve_main` (the repro-serve console script) trains the triple, then
serves the workload's eval set through api.serve's micro-batch path and
reports throughput + agreement with opened-model scoring.
"""

from __future__ import annotations

import argparse

from . import (PROTOCOLS, FaultPlan, engine_names, fit, serve,
               workload_names)
from . import workloads as workloads_mod


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("workload", nargs="?", default=None,
                    help="registry name (see --list)")
    ap.add_argument("--workload", dest="workload_flag", default=None,
                    metavar="NAME",
                    help="alternative spelling of the positional workload")
    ap.add_argument("--protocol", default="copml",
                    choices=sorted(PROTOCOLS))
    ap.add_argument("--engine", default="jit",
                    help='"eager" | "jit" | "sharded[:N]" | "proc[:N]" '
                         '(see --list for the live registry)')
    ap.add_argument("--iters", type=int, default=None,
                    help="GD iterations (default: the workload's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggle-p", type=float, default=None, metavar="P",
                    help="inject a seeded FaultPlan.random churn schedule "
                         "(per-step straggle probability; repaired to the "
                         "protocol's recovery threshold)")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for --straggle-p's schedule")
    ap.add_argument("--no-history", action="store_true",
                    help="skip the per-step model history / accuracy curve")
    ap.add_argument("-v", "--verbose", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print the three registries and exit")
    args = ap.parse_args(argv)
    if args.workload_flag is not None:
        if args.workload is not None:
            ap.error("give the workload positionally OR via --workload, "
                     "not both")
        args.workload = args.workload_flag
    if args.workload is None:
        args.workload = "quickstart"

    if args.list:
        from . import objective_names
        print("workloads: ", ", ".join(workload_names()))
        print("protocols: ", ", ".join(sorted(PROTOCOLS)))
        # the LIVE kind registry, so engines registered after import
        # (proc today, whatever comes next) appear without a CLI edit
        print("engines:   ", ", ".join(engine_names()))
        print("objectives:", ", ".join(objective_names()))
        return

    plan = None
    if args.straggle_p is not None:
        proto = PROTOCOLS[args.protocol]
        if not proto.supports_faults:
            ap.error(f"--straggle-p: protocol {args.protocol!r} has no "
                     f"fault injection")
        wl = workloads_mod.resolve(args.workload)
        iters = wl.iters if args.iters is None else args.iters
        # the SAME threshold protocol-side validation enforces
        thr = proto.fault_threshold(wl)
        plan = FaultPlan.random(wl.n_clients, iters, seed=args.fault_seed,
                                straggle_p=args.straggle_p,
                                min_available=thr)
        print(plan.describe(thr))

    res = fit(args.workload, args.protocol, args.engine, key=args.seed,
              iters=args.iters, history=not args.no_history, faults=plan)
    print(res.summary())
    if args.verbose and res.accuracy is not None:
        for t, a in enumerate(res.accuracy):
            print(f"  iter {t:3d}  accuracy {a:.3f}")


def serve_main(argv=None) -> None:
    """Train a triple, then serve its eval set from the secret-shared
    model (the repro-serve console script)."""
    import numpy as np

    ap = argparse.ArgumentParser(
        description="train a (workload, protocol, engine) triple, then "
                    "serve its eval set from the secret-shared model")
    ap.add_argument("workload", nargs="?", default="smoke",
                    help="registry name (default: smoke)")
    ap.add_argument("--protocol", default="copml",
                    choices=sorted(PROTOCOLS))
    ap.add_argument("--train-engine", default="jit", metavar="ENGINE",
                    help="engine for the training fit (default: jit)")
    ap.add_argument("--engine", default="jit",
                    help='serving engine: "eager" | "jit" | "sharded[:N]"')
    ap.add_argument("--iters", type=int, default=None,
                    help="GD iterations (default: the workload's)")
    ap.add_argument("--batch-size", type=int, default=32,
                    help="micro-batch window size (default: 32)")
    ap.add_argument("--window-ms", type=float, default=5.0,
                    help="micro-batch window in ms (default: 5)")
    ap.add_argument("--queries", type=int, default=None, metavar="Q",
                    help="serve only the first Q eval rows")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    res = fit(args.workload, args.protocol, args.train_engine,
              key=args.seed, iters=args.iters, history=False)
    print(res.summary())
    srv = serve(args.workload, res, args.engine, key=args.seed,
                batch_size=args.batch_size, window_ms=args.window_ms)
    wl = workloads_mod.resolve(args.workload)
    x, _ = wl.eval_set()
    if args.queries is not None:
        x = x[: args.queries]
    preds, _ = srv.serve(x)
    w = res.weights if res.weights.ndim > 1 else res.weights[:, None]
    open_preds = srv._decide(np.asarray(x, np.float64) @ w)
    if preds.dtype.kind == "f":      # regression: scores, not classes
        agree = float(np.isclose(preds, open_preds, atol=0.5).mean())
    else:
        agree = float((preds == open_preds).mean())
    print(srv.summary())
    print(f"agreement with opened-model scoring: {agree:.3f} "
          f"over {len(preds)} queries")


if __name__ == "__main__":
    main()
