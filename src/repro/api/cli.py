"""CLI front door: run any (workload, protocol, engine) triple.

    repro-fit smoke --protocol copml --engine jit          # console script
    PYTHONPATH=src python -m repro.api.cli --list          # registries

Prints the TrainResult summary line (and the accuracy curve with -v).
"""

from __future__ import annotations

import argparse

from . import ENGINES, PROTOCOLS, fit, workload_names


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("workload", nargs="?", default="quickstart",
                    help="registry name (see --list)")
    ap.add_argument("--protocol", default="copml",
                    choices=sorted(PROTOCOLS))
    ap.add_argument("--engine", default="jit",
                    help='"eager" | "jit" | "sharded[:N]"')
    ap.add_argument("--iters", type=int, default=None,
                    help="GD iterations (default: the workload's)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-history", action="store_true",
                    help="skip the per-step model history / accuracy curve")
    ap.add_argument("-v", "--verbose", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print the three registries and exit")
    args = ap.parse_args(argv)

    if args.list:
        print("workloads:", ", ".join(workload_names()))
        print("protocols:", ", ".join(sorted(PROTOCOLS)))
        print("engines:  ", ", ".join(ENGINES))
        return

    res = fit(args.workload, args.protocol, args.engine, key=args.seed,
              iters=args.iters, history=not args.no_history)
    print(res.summary())
    if args.verbose and res.accuracy is not None:
        for t, a in enumerate(res.accuracy):
            print(f"  iter {t:3d}  accuracy {a:.3f}")


if __name__ == "__main__":
    main()
