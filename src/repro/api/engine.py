"""Execution engines: the *how* axis of a run.

An engine decides how a protocol's training loop executes -- it never
changes WHAT is computed (engine swaps are bit-exact for COPML, see
tests/test_api.py and tests/test_runtime_engine.py):

  eager    Python loop, one jitted step per iteration.  Ground truth and
           step-through debugging.
  jit      the whole setup+scan loop as ONE compiled XLA program
           (single dispatch, in-graph model history).
  sharded  jit with the client axis PHYSICALLY split over a 1-D
           ("clients",) mesh; every exchange is a real collective
           (all_to_all / reduce-scatter / all_gather).  COPML only.
  proc     N OS processes over real localhost TCP sockets
           (launch/runtime); communication is MEASURED, not modeled,
           and stragglers emerge from network timing.  COPML only.

Engine kinds live in a registry (`register_kind` / `names`) so surfaces
that enumerate engines -- repro-fit --list, scripts/check_docs.py --
read the live set instead of a hardcoded tuple.  `EngineSpec` is the
value the facade passes around; `parse` accepts the spec itself, a plain
string ("eager" | "jit" | "sharded[:N]" | "proc[:N]"), or a jax Mesh
(treated as sharded over that mesh).
"""

from __future__ import annotations

import dataclasses

from ..core import meshutil
from ..launch.runtime.config import NetConfig  # noqa: F401  (re-export)


@dataclasses.dataclass(frozen=True)
class EngineKind:
    """One registered engine kind and what its specs may carry."""
    name: str
    doc: str
    takes_devices: bool = False     # accepts ":N" / devices=
    takes_mesh: bool = False        # accepts mesh=
    takes_net: bool = False         # accepts net= (a NetConfig)


KINDS: dict = {}


def register_kind(kind: EngineKind) -> EngineKind:
    """Add an engine kind to the registry (protocols opt in per-kind via
    their `engines` tuple; registration only teaches spec parsing and
    the enumeration surfaces about the name)."""
    KINDS[kind.name] = kind
    return kind


def names() -> tuple:
    """The LIVE engine-kind names, in registration order."""
    return tuple(KINDS)


register_kind(EngineKind(
    "eager", "Python loop, one jitted step per iteration"))
register_kind(EngineKind(
    "jit", "whole training loop as one compiled XLA program"))
register_kind(EngineKind(
    "sharded", "client axis sharded over a ('clients',) mesh",
    takes_devices=True, takes_mesh=True))
register_kind(EngineKind(
    "proc", "N OS processes over real TCP sockets (launch/runtime)",
    takes_devices=True, takes_net=True))

#: snapshot of the builtin kinds; enumeration surfaces should prefer the
#: live `names()` so later-registered kinds appear automatically
ENGINES = names()


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One execution strategy.  `devices` is the shard/process count
    (sharded and proc); `mesh` (sharded only) wins over `devices`; `net`
    (proc only) is a launch.runtime NetConfig with the link model and
    timeout policy."""
    kind: str
    devices: int | None = None
    mesh: object | None = None          # jax.sharding.Mesh
    net: object | None = None           # launch.runtime NetConfig

    def __post_init__(self):
        info = KINDS.get(self.kind)
        if info is None:
            raise ValueError(
                f"unknown engine kind {self.kind!r}; expected one of "
                f"{names()}")
        if self.devices is not None and self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if not (info.takes_devices or info.takes_mesh) and (
                self.devices is not None or self.mesh is not None):
            raise ValueError(f"engine {self.kind!r} takes no mesh/devices")
        if self.mesh is not None and not info.takes_mesh:
            raise ValueError(f"engine {self.kind!r} takes no mesh")
        if self.net is not None and not info.takes_net:
            raise ValueError(f"engine {self.kind!r} takes no net config")

    @property
    def label(self) -> str:
        """Stable row label: "jit" | "sharded:8" | "proc:4" | ..."""
        if self.mesh is not None:
            return f"{self.kind}:{self.mesh.size}"
        if self.devices is not None:
            return f"{self.kind}:{self.devices}"
        return self.kind

    def resolve_mesh(self):
        """The 1-D client mesh this spec runs on (sharded only)."""
        assert self.kind == "sharded", self.kind
        if self.mesh is not None:
            return self.mesh
        return meshutil.client_mesh(self.devices)


EAGER = EngineSpec("eager")
JIT = EngineSpec("jit")
SHARDED = EngineSpec("sharded")
PROC = EngineSpec("proc")


def parse(spec) -> EngineSpec:
    """Normalize a user-supplied engine spec to an EngineSpec."""
    if isinstance(spec, EngineSpec):
        return spec
    if hasattr(spec, "axis_names"):               # a jax Mesh
        return EngineSpec("sharded", mesh=spec)
    if isinstance(spec, str):
        kind, _, arg = spec.partition(":")
        if arg:
            info = KINDS.get(kind)
            if info is not None and not info.takes_devices:
                raise ValueError(f"engine {kind!r} takes no :N suffix")
            return EngineSpec(kind, devices=int(arg))
        return EngineSpec(kind)
    raise TypeError(f"cannot parse engine spec {spec!r}")
