"""Execution engines: the *how* axis of a run.

An engine decides how a protocol's training loop executes -- it never
changes WHAT is computed (engine swaps are bit-exact for COPML, see
tests/test_api.py):

  eager    Python loop, one jitted step per iteration.  Ground truth and
           step-through debugging.
  jit      the whole setup+scan loop as ONE compiled XLA program
           (single dispatch, in-graph model history).
  sharded  jit with the client axis PHYSICALLY split over a 1-D
           ("clients",) mesh; every exchange is a real collective
           (all_to_all / reduce-scatter / all_gather).  COPML only.

`EngineSpec` is the value the facade passes around; `parse` accepts the
spec itself, a plain string ("eager" | "jit" | "sharded" | "sharded:8"),
or a jax Mesh (treated as sharded over that mesh).
"""

from __future__ import annotations

import dataclasses

from ..core import meshutil

ENGINES = ("eager", "jit", "sharded")


@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """One execution strategy.  `devices`/`mesh` apply to sharded only:
    mesh wins if given, else a ("clients",) mesh over `devices` devices
    (None = all visible) is built at fit time."""
    kind: str
    devices: int | None = None
    mesh: object | None = None          # jax.sharding.Mesh

    def __post_init__(self):
        if self.kind not in ENGINES:
            raise ValueError(
                f"unknown engine kind {self.kind!r}; expected one of {ENGINES}")
        if self.devices is not None and self.devices < 1:
            raise ValueError(f"devices must be >= 1, got {self.devices}")
        if self.kind != "sharded" and (self.devices is not None
                                       or self.mesh is not None):
            raise ValueError(f"engine {self.kind!r} takes no mesh/devices")

    @property
    def label(self) -> str:
        """Stable row label: "eager" | "jit" | "sharded" | "sharded:8"."""
        if self.kind != "sharded":
            return self.kind
        if self.mesh is not None:
            return f"sharded:{self.mesh.size}"
        return "sharded" if self.devices is None else f"sharded:{self.devices}"

    def resolve_mesh(self):
        """The 1-D client mesh this spec runs on (sharded only)."""
        assert self.kind == "sharded", self.kind
        if self.mesh is not None:
            return self.mesh
        return meshutil.client_mesh(self.devices)


EAGER = EngineSpec("eager")
JIT = EngineSpec("jit")
SHARDED = EngineSpec("sharded")


def parse(spec) -> EngineSpec:
    """Normalize a user-supplied engine spec to an EngineSpec."""
    if isinstance(spec, EngineSpec):
        return spec
    if hasattr(spec, "axis_names"):               # a jax Mesh
        return EngineSpec("sharded", mesh=spec)
    if isinstance(spec, str):
        kind, _, arg = spec.partition(":")
        if arg:
            if kind != "sharded":
                raise ValueError(f"engine {kind!r} takes no :N suffix")
            return EngineSpec("sharded", devices=int(arg))
        return EngineSpec(kind)
    raise TypeError(f"cannot parse engine spec {spec!r}")
