"""Fault-injection plans: per-step straggler / dropout / adversary schedules.

COPML's headline resilience property is that a gradient round decodes from
ANY R = (2r+1)(K+T-1)+1 of the N coded contributions, and Shamir-shared
secure aggregation reconstructs from any T+1 of N shares.  A `FaultPlan`
turns that from a single static `subset=` into a *schedule*: for every
training step it says which clients straggle (miss the round), which have
permanently dropped out, and which contribute adversarially corrupted
values.  `api.fit(workload, protocol, engine, faults=plan)` then replays
the schedule on any engine:

  eager    the per-step decode subset is swapped every iteration (one
           jitted step with dynamic gather indices -- no recompiles);
  jit      the plan is precompiled to (iters, R) decode-index / decode-
           vector arrays plus the (iters, N) availability mask and threaded
           through the lax.scan, so the whole faulty run stays a single
           compiled dispatch;
  sharded  same scan inputs, replicated across the client mesh.

Semantics (documented in docs/API.md, enforced in validate()):

* a straggling client's contribution simply misses that round's decode;
* a dropout is a straggler for every remaining step;
* an adversary's contribution is *actually corrupted in-graph* (offset by
  core.protocol.ADV_OFFSET, large enough to survive TruncPr's rescale)
  and excluded from the decode subset -- the bit-exactness tests prove
  the exclusion is real, not cosmetic;
* decoding from any valid subset yields the identical field element, so a
  faulty run is bit-exact with the fault-free run of the same key -- zero
  recovery cost, the paper's claim as an executable property.

Validation (train/elastic.py budgets, promoted to hard errors): a plan
whose per-step availability ever drops below the protocol's recovery
threshold raises `FaultPlanViolation` before any compute.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..train.elastic import (FaultPlanViolation, plan_headroom,
                             validate_budget)

__all__ = ["FaultPlan", "FaultPlanViolation", "plan_headroom",
           "validate_budget"]


def _normalize_schedule(sched, iters: int, n: int, what: str) -> dict:
    """{step: iterable-of-client-ids} with bounds checks."""
    out = {}
    for step, clients in (sched or {}).items():
        step = int(step)
        if not 0 <= step < iters:
            raise ValueError(f"{what} schedule step {step} outside "
                             f"[0, {iters})")
        ids = tuple(int(c) for c in clients)
        for c in ids:
            if not 0 <= c < n:
                raise ValueError(f"{what} schedule names client {c} "
                                 f"outside [0, {n})")
        out[step] = ids
    return out


@dataclasses.dataclass(frozen=True, eq=False)
class FaultPlan:
    """A per-step fault schedule over N clients and `iters` training steps.

    available: (iters, N) bool -- True where the client contributes an
               honest result on time (eligible for that step's decode).
    adversary: (iters, N) bool -- True where the client contributes a
               CORRUPTED result (never eligible for decode).  Disjoint
               from `available` by construction.
    """
    n_clients: int
    iters: int
    available: np.ndarray
    adversary: np.ndarray

    def __post_init__(self):
        # copy: freezing an np.asarray view would make the CALLER's array
        # read-only as a side effect
        avail = np.array(self.available, dtype=bool, copy=True)
        adv = np.array(self.adversary, dtype=bool, copy=True)
        shape = (self.iters, self.n_clients)
        if avail.shape != shape or adv.shape != shape:
            raise ValueError(f"plan masks must be {shape}; got "
                             f"{avail.shape} / {adv.shape}")
        if (avail & adv).any():
            raise ValueError("a client cannot be both available and "
                             "adversarial in the same step")
        avail.flags.writeable = False
        adv.flags.writeable = False
        object.__setattr__(self, "available", avail)
        object.__setattr__(self, "adversary", adv)

    # ------------------------------------------------------------ builders

    @classmethod
    def fault_free(cls, n_clients: int, iters: int) -> "FaultPlan":
        return cls(n_clients, iters,
                   np.ones((iters, n_clients), bool),
                   np.zeros((iters, n_clients), bool))

    @classmethod
    def from_schedule(cls, n_clients: int, iters: int, *,
                      stragglers=None, dropouts=None,
                      adversaries=None) -> "FaultPlan":
        """Build a plan from explicit step->clients maps.

        stragglers[s]:  clients missing step s only.
        dropouts[s]:    clients gone from step s ONWARD (permanent).
        adversaries[s]: clients corrupted from step s ONWARD (permanent --
                        a compromised client stays compromised).
        """
        avail = np.ones((iters, n_clients), bool)
        adv = np.zeros((iters, n_clients), bool)
        for s, ids in _normalize_schedule(stragglers, iters, n_clients,
                                          "straggler").items():
            avail[s, list(ids)] = False
        for s, ids in _normalize_schedule(dropouts, iters, n_clients,
                                          "dropout").items():
            avail[s:, list(ids)] = False
        for s, ids in _normalize_schedule(adversaries, iters, n_clients,
                                          "adversary").items():
            avail[s:, list(ids)] = False
            adv[s:, list(ids)] = True
        return cls(n_clients, iters, avail, adv)

    @classmethod
    def random(cls, n_clients: int, iters: int, *, seed: int = 0,
               straggle_p: float = 0.0, n_dropouts: int = 0,
               n_adversaries: int = 0,
               min_available: int | None = None) -> "FaultPlan":
        """Seeded churn: i.i.d. per-(step, client) straggling at
        `straggle_p`, plus `n_dropouts` clients dying and `n_adversaries`
        turning corrupt at random steps.  With `min_available` set, steps
        that would fall below it are repaired by reviving the lowest-index
        stragglers (dropouts and adversaries are never revived), so seeded
        plans stay above a known recovery threshold by construction."""
        rng = np.random.default_rng(seed)
        avail = rng.random((iters, n_clients)) >= straggle_p
        adv = np.zeros((iters, n_clients), bool)
        if n_dropouts + n_adversaries > n_clients:
            raise ValueError("more dropouts+adversaries than clients")
        perm = rng.permutation(n_clients)
        dropped = perm[:n_dropouts]
        corrupt = perm[n_dropouts:n_dropouts + n_adversaries]
        # non-revivable only from each client's fault-start step ONWARD --
        # before its dropout a client is an ordinary straggler
        permanent = np.zeros((iters, n_clients), bool)
        for c in dropped:
            s = int(rng.integers(0, iters))
            avail[s:, c] = False
            permanent[s:, c] = True
        for c in corrupt:
            s = int(rng.integers(0, iters))
            avail[s:, c] = False
            adv[s:, c] = True
            permanent[s:, c] = True
        if min_available is not None:
            for s in range(iters):
                short = min_available - int(avail[s].sum())
                if short > 0:
                    revivable = np.flatnonzero(~avail[s] & ~permanent[s])
                    if revivable.size < short:
                        raise FaultPlanViolation(
                            f"cannot repair step {s} to {min_available} "
                            f"available clients: only {revivable.size} "
                            f"revivable stragglers")
                    avail[s, revivable[:short]] = True
        return cls(n_clients, iters, avail, adv)

    # ------------------------------------------------------------- queries

    @property
    def available_counts(self) -> np.ndarray:
        """(iters,) honest on-time contributors per step."""
        return self.available.sum(axis=1).astype(np.int64)

    @property
    def has_adversaries(self) -> bool:
        return bool(self.adversary.any())

    @property
    def is_fault_free(self) -> bool:
        return bool(self.available.all()) and not self.has_adversaries

    def headroom(self, threshold: int) -> np.ndarray:
        """Per-step spare contributors above `threshold` (may be negative)."""
        return plan_headroom(self.available_counts, threshold)

    def validate(self, threshold: int, what: str = "decode") -> np.ndarray:
        """elastic.validate_budget on this plan's availability; raises
        FaultPlanViolation (before any compute) or returns the headroom."""
        return validate_budget(self.available_counts, threshold, what)

    def subsets(self, r: int) -> tuple:
        """Per-step decode subsets: the first `r` available client indices
        each step (deterministic, so every engine replays the same plan
        identically).  Requires a validated plan (>= r available)."""
        out = []
        for s in range(self.iters):
            ids = np.flatnonzero(self.available[s])
            if ids.size < r:
                raise FaultPlanViolation(
                    f"step {s} has {ids.size} available clients < {r}")
            out.append(tuple(int(i) for i in ids[:r]))
        return tuple(out)

    def slice(self, iters: int) -> "FaultPlan":
        """The plan's first `iters` steps (fit may run fewer steps than the
        plan covers; it may never run more)."""
        if iters > self.iters:
            raise ValueError(f"plan covers {self.iters} steps; cannot "
                             f"slice to {iters}")
        if iters == self.iters:
            return self
        return FaultPlan(self.n_clients, iters,
                         self.available[:iters], self.adversary[:iters])

    def describe(self, threshold: int | None = None) -> str:
        counts = self.available_counts
        parts = [f"FaultPlan(N={self.n_clients}, iters={self.iters}, "
                 f"available {int(counts.min())}..{int(counts.max())}"]
        if self.has_adversaries:
            parts.append(f", {int(self.adversary.any(axis=0).sum())} "
                         f"adversarial client(s)")
        if threshold is not None:
            parts.append(f", min headroom {int(self.headroom(threshold).min())}"
                         f" over threshold {threshold}")
        return "".join(parts) + ")"
