"""Protocol registry: the *who-computes-what* axis of a run.

Five interchangeable training protocols over the same workloads, the
paper's Section V comparison as a registry (fit one name against another
and the Fig. 3/4 / Table I artifacts are pure formatting of TrainResults):

  copml         Algorithm 1: LCC-coded secret-shared training, local-only
                hot loop (core/protocol.Copml).  eager | jit | sharded.
  mpc_baseline  the [BGW88]/[BH08] Appendix-D baselines: every multiply
                is a secure multiplication with degree reduction
                (core/baselines.MpcBaseline).  eager | jit.
  float         conventional plaintext logistic regression (the Fig. 4
                reference).  eager | jit.
  poly_float    plaintext GD with the degree-r polynomial sigmoid --
                isolates approximation from quantization error.
                eager | jit.
  secure_agg    gradient-privacy-only training: clear local gradients,
                COPML-coded secure aggregation of the exchange
                (core/secure_agg).  eager | jit.

Every protocol consumes the workload's SecureObjective (core/objectives):
the same registry trains binary logreg, linear regression, and multi-class
one-vs-rest matrix models with no protocol-specific casing beyond shapes.

All protocol drivers and dataset arrays are cached per (hashable)
Workload, so repeated fits of the same shape reuse compiled programs.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from ..core import baselines, cost_model, secure_agg
from ..core import objectives as objectives_mod
from ..core.protocol import Copml
from ..train import elastic
from . import engine as engine_mod
from . import faults as faults_mod
from . import result as result_mod
from . import workloads as workloads_mod

PROTOCOLS: dict = {}


def register(protocol: "Protocol") -> "Protocol":
    PROTOCOLS[protocol.name] = protocol
    return protocol


def get(name: str) -> "Protocol":
    if name not in PROTOCOLS:
        known = ", ".join(sorted(PROTOCOLS))
        raise KeyError(f"unknown protocol {name!r}; registered: {known}")
    return PROTOCOLS[name]


def names() -> tuple:
    return tuple(sorted(PROTOCOLS))


# ---------------------------------------------------------------- the facade


def fit(workload, protocol: str = "copml", engine="jit", *, key=0,
        iters: int | None = None, subset=None, history: bool = True,
        faults=None) -> result_mod.TrainResult:
    """Train `workload` with `protocol` on `engine`; the one front door.

    workload: registry name or an ad-hoc workloads.Workload instance.
    protocol: name in PROTOCOLS.
    engine:   "eager" | "jit" | "sharded[:N]" | EngineSpec | jax Mesh.
    key:      int seed or jax PRNGKey.
    iters:    GD iterations (None = the workload's default).
    subset:   straggler decode subset.  None inherits the workload's
              default (subset-capable protocols only); "all" or () forces
              full decode even when the workload has a default subset.
    history:  keep the per-step opened-model trajectory + accuracy curve.
    faults:   a faults.FaultPlan (per-step straggler/dropout/adversary
              schedule) replayed by the engine; validated against the
              protocol's recovery threshold BEFORE any compute
              (FaultPlanViolation).  Mutually exclusive with `subset`.
    """
    return get(protocol).fit(workload, engine, key=key, iters=iters,
                             subset=subset, history=history, faults=faults)


class Protocol:
    """One training protocol behind the common fit() interface.

    Subclasses implement `_run` (returning the raw engine outputs) and
    optionally `cost`; the base class owns workload/engine resolution,
    timing, and TrainResult assembly."""

    name: str = "?"
    engines: tuple = ("eager", "jit")
    supports_subset: bool = False    # straggler decode subsets
    supports_faults: bool = False    # per-step FaultPlan schedules

    def fit(self, workload, engine="jit", *, key=0, iters=None, subset=None,
            history=True, faults=None) -> result_mod.TrainResult:
        wl = workloads_mod.resolve(workload)
        spec = engine_mod.parse(engine)
        if spec.kind not in self.engines:
            raise ValueError(
                f"protocol {self.name!r} supports engines {self.engines}, "
                f"not {spec.kind!r}")
        if isinstance(key, int):
            key = jax.random.PRNGKey(key)
        iters = wl.iters if iters is None else int(iters)
        if faults is not None:
            if subset is not None:
                raise ValueError(
                    "faults= and subset= are mutually exclusive: the plan "
                    "chooses each step's decode subset")
            plan = self._resolve_plan(wl, iters, faults)
            subset = None                    # the plan drives every step
        else:
            plan = None
            if subset is None:
                # the workload default only applies where it means something
                subset = wl.subset if self.supports_subset else None
            elif isinstance(subset, str):
                if subset != "all":
                    raise ValueError(f"subset must be None, 'all', or an "
                                     f"iterable of client indices; got "
                                     f"{subset!r}")
                subset = None                     # force full decode
            else:
                subset = tuple(subset) or None    # () also means full decode
            if subset is not None and not self.supports_subset:
                raise ValueError(
                    f"protocol {self.name!r} has no straggler-subset "
                    f"decoding; drop the subset argument")

        t0 = time.perf_counter()
        # plan is passed only when present: externally registered protocols
        # written against the pre-fault 6-arg _run contract keep working
        # for fault-free fits (docs/API.md extension example)
        if plan is None:
            out = self._run(wl, spec, key, iters, subset, history)
        else:
            out = self._run(wl, spec, key, iters, subset, history, plan)
        # engines that MEASURE their communication (proc) return a 4th
        # element; the in-process engines keep the 3-tuple contract
        if len(out) == 4:
            w, hist, state, measured = out
        else:
            w, hist, state = out
            measured = None
        w = np.asarray(jax.block_until_ready(w))
        wall = time.perf_counter() - t0

        hist = None if hist is None else np.asarray(hist)
        x_eval, y_eval = wl.eval_set()
        obj = wl.objective        # objective-defined scoring: accuracy for
        #                           the logistic objectives, R^2 for linreg
        acc = None if hist is None else np.asarray(
            [obj.score(w_t, x_eval, y_eval) for w_t in hist])
        return result_mod.TrainResult(
            workload=wl.name, protocol=self.name, engine=spec.label,
            iters=iters, weights=w, wall_time_s=wall, history=hist,
            accuracy=acc,
            final_accuracy=obj.score(w, x_eval, y_eval),
            per_class_accuracy=obj.per_class_accuracy(w, x_eval, y_eval),
            cost=self.cost(wl, iters), state=state,
            availability=None if plan is None else plan.available.copy(),
            measured_comm=measured)

    def _resolve_plan(self, wl, iters: int, faults) -> faults_mod.FaultPlan:
        """Check a FaultPlan against this protocol and workload, truncate
        it to the run length, and run the recovery-threshold budget check
        -- all BEFORE any engine work (an invalid plan never compiles)."""
        if not self.supports_faults:
            raise ValueError(
                f"protocol {self.name!r} has no fault injection; drop the "
                f"faults argument")
        if not isinstance(faults, faults_mod.FaultPlan):
            raise TypeError(f"faults must be a FaultPlan, got "
                            f"{type(faults).__name__}")
        if faults.n_clients != wl.n_clients:
            raise ValueError(
                f"plan covers {faults.n_clients} clients; workload "
                f"{wl.name!r} has {wl.n_clients}")
        if faults.iters < iters:
            raise ValueError(
                f"plan covers {faults.iters} steps; the run needs {iters}")
        plan = faults.slice(iters)
        self._validate_plan(wl, plan)        # raises FaultPlanViolation
        return plan

    def fault_threshold(self, wl) -> int:
        """The per-step availability floor a FaultPlan must keep for this
        protocol on `wl` -- the SINGLE source both _validate_plan and
        plan-building callers (cli --straggle-p) derive from."""
        raise NotImplementedError            # supports_faults protocols only

    def _validate_plan(self, wl, plan: faults_mod.FaultPlan):
        raise NotImplementedError            # supports_faults protocols only

    def _run(self, wl, spec, key, iters, subset, history, plan=None):
        """-> (weights, history-or-None, protocol-native state)"""
        raise NotImplementedError

    def cost(self, wl, iters: int) -> dict | None:
        """Modeled per-client comm/comp/enc on the paper's WAN params."""
        return None

    def _cost_workload(self, wl, iters: int) -> cost_model.Workload:
        return cost_model.Workload(m=wl.m, d=wl.d, n=wl.n_clients,
                                   k=wl.cfg.k, t=wl.cfg.t, iters=iters,
                                   r=wl.cfg.r, c=wl.objective.n_outputs)


def _stack_history(rows, w_shape):
    """Collected eager-engine history rows -> the same (iters,) + w_shape
    array the scan engines produce (None stays None; zero iterations give
    (0,) + w_shape, not None, so the TrainResult schema is
    engine-independent)."""
    if rows is None:
        return None
    return np.stack(rows) if rows else \
        np.zeros((0,) + tuple(w_shape), np.float32)


def _history_recorder(history: bool):
    """(rows, callback) for the eager engines: the callback appends each
    step's opened model to rows; both are None when history is off.  The
    copy matters: the numpy trainers (float_logreg et al.) update w in
    place, so an np.asarray view would alias every row to the final
    model."""
    if not history:
        return None, None
    rows: list = []
    return rows, lambda t, w: rows.append(np.array(w, copy=True))


# ------------------------------------------------------------------ copml


def run_copml_engine(proto: Copml, spec, key, client_xs, client_ys,
                     iters: int, subset=None, history: bool = False,
                     callback=None, step_subsets=None, adversaries=None):
    """THE dispatch from an EngineSpec to a Copml engine implementation.

    Both api.fit and the deprecated Copml.train_* shims route through
    here, so shim-vs-facade parity is structural.  Returns
    (state, weights, history-or-None); `callback` is eager-only.
    step_subsets/adversaries carry a FaultPlan's per-step decode subsets
    and corruption mask to whichever engine runs."""
    spec = engine_mod.parse(spec)
    subset = None if subset is None else tuple(subset)
    fault_kw = dict(step_subsets=step_subsets, adversaries=adversaries)
    if spec.kind == "eager":
        hist_rows, rec = _history_recorder(history)

        def cb(t, w):
            if rec is not None:
                rec(t, w)
            if callback is not None:
                callback(t, w)

        state, w = proto._train_eager(
            key, client_xs, client_ys, iters, subset=subset,
            callback=cb if (history or callback) else None, **fault_kw)
        return state, w, _stack_history(hist_rows, proto.w_shape)
    if callback is not None:
        raise ValueError("callback is only supported on the eager engine")
    if spec.kind == "jit":
        out = proto._train_jit(key, client_xs, client_ys, iters,
                               subset=subset, history=history, **fault_kw)
    else:
        out = proto._train_sharded(key, client_xs, client_ys, iters,
                                   mesh=spec.resolve_mesh(), subset=subset,
                                   history=history, **fault_kw)
    if history:
        state, w, hist = out
        return state, w, hist
    state, w = out
    return state, w, None


class CopmlProtocol(Protocol):
    name = "copml"
    engines = ("eager", "jit", "sharded", "proc")
    supports_subset = True           # decode from any R of N clients
    supports_faults = True           # per-step FaultPlan schedules

    def __init__(self):
        self._drivers: dict = {}

    def driver(self, wl) -> Copml:
        """The (cached) Copml instance for a workload -- caching keeps the
        per-instance jit/scan caches warm across fit() calls."""
        if wl not in self._drivers:
            self._drivers[wl] = Copml(wl.cfg, wl.m, wl.d,
                                      objective=wl.objective)
        return self._drivers[wl]

    def fault_threshold(self, wl) -> int:
        """R = (2r+1)(K+T-1)+1 honest on-time clients per step."""
        return elastic.straggler_budget(wl.n_clients, wl.cfg.k, wl.cfg.t,
                                        wl.cfg.r).recovery_threshold

    def _validate_plan(self, wl, plan):
        """The paper's recovery threshold as a hard budget (elastic.py)."""
        plan.validate(self.fault_threshold(wl), "COPML decode")

    def _run(self, wl, spec, key, iters, subset, history, plan=None):
        proto = self.driver(wl)
        cx, cy = wl.client_data()
        if spec.kind == "proc":
            if plan is not None:
                raise ValueError(
                    "the proc engine has no FaultPlan replay: stragglers "
                    "emerge from real socket timing -- inject latency / "
                    "deadlines via EngineSpec('proc', net=NetConfig(...)) "
                    "instead")
            from ..launch import runtime
            state, w, hist, measured = runtime.run_copml_proc(
                proto, key, cx, cy, iters, procs=spec.devices,
                net_cfg=spec.net, subset=subset, history=history)
            return w, hist, state, measured
        step_subsets = adversaries = None
        if plan is not None:
            step_subsets = plan.subsets(wl.cfg.recovery_threshold)
            adversaries = plan.adversary if plan.has_adversaries else None
        state, w, hist = run_copml_engine(proto, spec, key, cx, cy, iters,
                                          subset=subset, history=history,
                                          step_subsets=step_subsets,
                                          adversaries=adversaries)
        return w, hist, state

    def cost(self, wl, iters):
        return cost_model.copml_costs(self._cost_workload(wl, iters))


class MpcBaselineProtocol(Protocol):
    name = "mpc_baseline"
    scheme = "bh08"
    groups = 3

    def __init__(self):
        self._drivers: dict = {}

    def driver(self, wl) -> baselines.MpcBaseline:
        if wl not in self._drivers:
            self._drivers[wl] = baselines.MpcBaseline(
                wl.cfg, wl.m, wl.d, groups=self.groups, scheme=self.scheme,
                objective=wl.objective)
        return self._drivers[wl]

    def _run(self, wl, spec, key, iters, subset, history, plan=None):
        mb = self.driver(wl)
        x, y, _, _ = wl.data()
        if spec.kind == "jit":
            out = mb.train_scan(key, x, y, iters, history=history)
            return (out[1], out[2], out[0]) if history else \
                (out[1], None, out[0])
        rows, cb = _history_recorder(history)
        state, w = mb.train(key, x, y, iters, callback=cb)
        return w, _stack_history(rows, wl.w_shape), state

    def cost(self, wl, iters):
        return cost_model.mpc_baseline_costs(
            self._cost_workload(wl, iters), scheme=self.scheme,
            groups=self.groups)


class FloatProtocol(Protocol):
    name = "float"
    poly = False        # PolyFloatProtocol flips this: same float engine,
    #                     ghat's polynomial instead of the exact activation

    def _run(self, wl, spec, key, iters, subset, history, plan=None):
        x, y, _, _ = wl.data()
        obj, eta = wl.objective, wl.cfg.eta
        r, bound = wl.cfg.r, wl.cfg.sigmoid_bound
        if not isinstance(obj, objectives_mod.BinaryLogistic):
            # objective-generic float GD (vector or matrix model)
            if spec.kind == "jit":
                w, hist = baselines.float_objective_scan(
                    obj, x, y, eta, iters, history=history, poly=self.poly,
                    r=r, bound=bound)
                return w, hist, None
            rows, cb = _history_recorder(history)
            w = baselines.float_objective_train(
                obj, x, y, eta, iters, callback=cb, poly=self.poly, r=r,
                bound=bound)
            return w, _stack_history(rows, wl.w_shape), None
        # the paper's binary path keeps its dedicated (pre-objective)
        # trainers -- their compiled programs are shared across the suite
        if spec.kind == "jit":
            if self.poly:
                w, hist = baselines.float_poly_logreg_scan(
                    x, y, eta, iters, r=r, bound=bound, history=history)
            else:
                w, hist = baselines.float_logreg_scan(x, y, eta, iters,
                                                      history=history)
            return w, hist, None
        rows, cb = _history_recorder(history)
        if self.poly:
            w = baselines.float_poly_logreg(x, y, eta, iters, r=r,
                                            bound=bound, callback=cb)
        else:
            w = baselines.float_logreg(x, y, eta, iters, callback=cb)
        return w, _stack_history(rows, wl.w_shape), None


class PolyFloatProtocol(FloatProtocol):
    name = "poly_float"
    poly = True


class SecureAggProtocol(Protocol):
    name = "secure_agg"
    supports_subset = True           # reconstruct from any T+1 holders
    supports_faults = True           # per-step T+1-of-N share selection

    def agg_config(self, wl) -> secure_agg.SecureAggConfig:
        """Privacy threshold T from the workload's COPML parameterization;
        lq/clip at the module defaults (validated against the field)."""
        return secure_agg.SecureAggConfig(n_clients=wl.n_clients, t=wl.cfg.t)

    def _validate_plan(self, wl, plan):
        """Shamir aggregation reconstructs from any T+1 holders' shares
        (elastic.secure_agg_budget); the plan governs which holders'
        shares each round's reconstruction reads.  There is no redundancy
        on the OWNER side (every gradient is summed exactly once), so
        corrupted contributions cannot be excluded -- adversarial plans
        are rejected for this protocol."""
        if plan.has_adversaries:
            raise elastic.FaultPlanViolation(
                "secure_agg tolerates straggling/dropped share holders, "
                "not adversarially corrupted contributions (no decode "
                "redundancy over gradient owners); use the copml protocol "
                "for adversary schedules")
        plan.validate(self.fault_threshold(wl), "secure_agg share")

    def fault_threshold(self, wl) -> int:
        """T+1 share holders per step (Shamir reconstruction)."""
        return elastic.secure_agg_budget(wl.n_clients,
                                         wl.cfg.t).recovery_threshold

    def _run(self, wl, spec, key, iters, subset, history, plan=None):
        cx, cy = wl.client_data()
        cfg, eta = self.agg_config(wl), wl.cfg.eta
        step_subsets = None if plan is None else plan.subsets(cfg.t + 1)
        obj = wl.objective
        if spec.kind == "jit":
            w, hist = secure_agg.secure_logreg_scan(
                key, cx, cy, cfg, eta, iters, subset=subset,
                history=history, step_subsets=step_subsets, objective=obj)
            return w, hist, cfg
        rows, cb = _history_recorder(history)
        w = secure_agg.secure_logreg(key, cx, cy, cfg, eta, iters,
                                     subset=subset, callback=cb,
                                     step_subsets=step_subsets,
                                     objective=obj)
        return w, _stack_history(rows, wl.w_shape), cfg


register(CopmlProtocol())
register(MpcBaselineProtocol())
register(FloatProtocol())
register(PolyFloatProtocol())
register(SecureAggProtocol())
