"""repro.api -- one front door for every experiment in the repo.

A run is fully specified by three orthogonal axes:

    workload  x  protocol  x  engine
    (what)       (how it's secured)   (how it executes)

    from repro import api
    res = api.fit("cifar10_like", "copml", "jit")
    res = api.fit("smoke", "mpc_baseline", "eager", iters=5)
    res = api.fit("smoke", "copml", "sharded:8")      # real collectives

Every fit returns the same TrainResult schema (opened model, per-step
history, accuracy curve, wall time, modeled comm/comp cost), so the
paper's Fig. 3/4 and Table I/II are pure formatting.  A workload also
carries a SecureObjective (core/objectives: binary logreg, linreg, or
C-class one-vs-rest on a (d, C) matrix model) -- the model-specific slice
every protocol consumes:

    res = api.fit("mnist10_like", "copml", "jit")     # 10-class, coded
    res.per_class_accuracy                            # (10,)

Trained models serve without being opened: `api.serve(workload, res,
engine)` re-shares the result's protocol-native share state into a
SecureServer (micro-batched coded inference, see docs/API.md Serving).

New protocols, workloads, objectives, and engines plug in via the
registries (api.register_protocol / api.register_workload /
api.register_objective) without another bespoke driver -- see docs/API.md
for the axes, registry names, and the migration table from the old
Copml.train_* call conventions.
"""

from ..core.objectives import (OBJECTIVES, SecureObjective,
                               multiclass_logistic)
from ..core.objectives import get as get_objective
from ..core.objectives import names as objective_names
from ..core.objectives import register as register_objective
from .engine import (EAGER, ENGINES, JIT, PROC, SHARDED, EngineKind,
                     EngineSpec, NetConfig)
from .engine import names as engine_names
from .engine import parse as parse_engine
from .engine import register_kind as register_engine_kind
from .faults import FaultPlan, FaultPlanViolation
from .protocols import PROTOCOLS, Protocol, fit, run_copml_engine
from .protocols import names as protocol_names
from .protocols import register as register_protocol
from .result import TrainResult, accuracy_curve, accuracy_of
from .serving import SERVE_ENGINES, serve
from .workloads import WORKLOADS, Workload
from .workloads import get as get_workload
from .workloads import names as workload_names
from .workloads import register as register_workload

__all__ = [
    "EAGER", "ENGINES", "JIT", "OBJECTIVES", "PROC", "PROTOCOLS",
    "SERVE_ENGINES", "SHARDED", "EngineKind", "EngineSpec", "FaultPlan",
    "FaultPlanViolation", "NetConfig", "Protocol", "SecureObjective",
    "TrainResult", "WORKLOADS", "Workload", "accuracy_curve", "accuracy_of",
    "engine_names", "fit", "get_objective", "get_workload",
    "multiclass_logistic", "objective_names", "parse_engine",
    "protocol_names", "register_engine_kind", "register_objective",
    "register_protocol", "register_workload", "run_copml_engine", "serve",
    "workload_names",
]
