"""api.serve: the serving front door, mirroring api.fit's three axes.

    from repro import api
    res = api.fit("mnist10_like", "copml", "jit")
    srv = api.serve("mnist10_like", res, "jit")
    preds, stats = srv.serve(queries)          # micro-batched, in order

The (workload, result, engine) triple fully specifies a server: the
workload supplies the protocol parameterization (cfg: N/T/scales) and
the objective (decision semantics), the TrainResult supplies the model
-- preferably its protocol-native share state, so the model is re-shared
without ever being opened -- and the engine picks eager / jit / sharded
execution exactly as in fit().  proc:N serving is future work; the
per-client share layout (CodedModel.w_stack rows) already matches the
runtime's one-row-per-process convention, so nothing here precludes it.
"""

from __future__ import annotations

import jax
import numpy as np

from ..serve import coded
from ..serve.server import SERVE_KINDS, SecureServer
from . import engine as engine_mod
from . import workloads as workloads_mod

#: engine kinds api.serve accepts today (see SERVE_KINDS in serve/server)
SERVE_ENGINES = SERVE_KINDS


def serve(workload, result, engine="jit", *, key: int = 0,
          batch_size: int = 32, window_ms: float = 5.0) -> SecureServer:
    """Build a SecureServer from a workload and its TrainResult.

    workload    registry name or Workload instance (must be the one the
                result was trained on -- shape-checked)
    result      an api.fit TrainResult; a COPML result's share state is
                re-shared directly (encode path never opens the model)
    engine      "eager" | "jit" | "sharded[:N]" (spec string, EngineSpec,
                or a jax Mesh); "proc" is rejected as future work
    key         PRNG seed of the one-time re-share randomness
    batch_size  micro-batch window size (queries per scoring dispatch)
    window_ms   max milliseconds a query waits for its window to fill
    """
    wl = workloads_mod.resolve(workload)
    spec = engine_mod.parse(engine)
    if spec.kind not in SERVE_ENGINES:
        raise ValueError(
            f"engine kind {spec.kind!r} cannot serve yet (supported: "
            f"{SERVE_ENGINES}); proc:N serving is future work -- the "
            f"per-client share layout already matches the runtime's "
            f"one-row-per-process convention")
    w = np.asarray(result.weights)
    if w.shape != wl.w_shape:
        raise ValueError(
            f"result.weights shape {w.shape} does not match workload "
            f"{wl.name!r} model shape {wl.w_shape} -- was this result "
            f"trained on a different workload?")
    rwl = getattr(result, "workload", wl.name)
    if rwl != wl.name:
        raise ValueError(
            f"result was trained on workload {rwl!r}, not {wl.name!r}")
    model = coded.encode_model(jax.random.PRNGKey(key), result, wl.cfg,
                               wl.objective)
    mesh = spec.resolve_mesh() if spec.kind == "sharded" else None
    return SecureServer(workload=wl.name, protocol=result.protocol,
                        engine=spec.label, kind=spec.kind,
                        batch_size=batch_size, window_ms=window_ms,
                        model=model, objective=wl.objective, mesh=mesh)
