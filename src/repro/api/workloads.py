"""Workload registry: the *what* axis of a run.

A Workload fully specifies a training task: dataset shape + generation
parameters (data/pipeline synthetic builders -- real corpora are not
available offline), the COPML protocol parameterization (N, K, T, scales,
eta), the default iteration budget, and an optional default straggler
subset.  Together with a protocol name and an EngineSpec it pins down a
run completely: `api.fit(workload, protocol, engine)`.

The paper-scale shapes come straight from configs/copml_logreg.py (the
single source of truth for Section V-A dataset dimensions); the reduced
*_like entries mirror the shapes the benchmarks train for real on a CPU
budget (benchmarks/fig4_accuracy.py).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..configs import copml_logreg
from ..core import objectives
from ..core.protocol import (CopmlConfig, case1_params, case2_params,
                             derive_update_constants)
from ..data import pipeline


@dataclasses.dataclass(frozen=True)
class Workload:
    """A named, fully-specified training task (hashable: protocol drivers
    and dataset arrays are cached per workload across fit() calls)."""
    name: str
    m: int                      # total training rows (across all clients)
    d: int                      # feature dimension
    cfg: CopmlConfig            # N / K / T / scales / eta
    seed: int = 0               # synthetic dataset seed
    margin: float = 2.0         # class separation of the planted separator
    test_m: int = 0             # held-out eval rows (0 = eval on train)
    iters: int = 30             # default GD iterations
    subset: tuple | None = None  # default straggler subset (decode clients)
    objective: objectives.SecureObjective = objectives.BINARY_LOGISTIC
    # the model family (core/objectives): binary logreg (default, the
    # paper's task), linreg, or C-class one-vs-rest on a (d, C) matrix

    @property
    def n_clients(self) -> int:
        return self.cfg.n_clients

    @property
    def w_shape(self) -> tuple:
        """The opened model's shape: (d,) or (d, C)."""
        return self.objective.w_shape(self.d)

    def data(self):
        """(x, y, x_test, y_test); the eval pair is (None, None) when
        test_m == 0.  Cached: repeated fits reuse the same arrays."""
        return _dataset(self.m, self.d, self.seed, self.margin, self.test_m,
                        self.objective.dataset_kind,
                        self.objective.n_outputs)

    def eval_set(self):
        """The eval pair accuracy curves are scored against: the held-out
        split when one exists, else the training set."""
        x, y, xt, yt = self.data()
        return (xt, yt) if xt is not None else (x, y)

    def client_data(self):
        """Per-client row splits (paper Section V-A even distribution)."""
        x, y, _, _ = self.data()
        return pipeline.split_clients(x, y, self.n_clients)


_DATA_CACHE: dict = {}


def _dataset(m, d, seed, margin, test_m, kind="binary", n_outputs=1):
    key = (m, d, seed, margin, test_m, kind, n_outputs)
    if key not in _DATA_CACHE:
        if kind == "multiclass":
            out = pipeline.multiclass_dataset(m=m, d=d, n_classes=n_outputs,
                                              seed=seed, margin=margin,
                                              test_m=test_m)
        elif kind == "regression":
            out = pipeline.regression_dataset(m=m, d=d, seed=seed,
                                              test_m=test_m)
        else:
            out = pipeline.classification_dataset(
                m=m, d=d, seed=seed, margin=margin, test_m=test_m)
        if not test_m:
            out = (out[0], out[1], None, None)
        for arr in out:                 # the cache is shared across fits:
            if arr is not None:         # freeze so no caller can corrupt it
                arr.flags.writeable = False
        _DATA_CACHE[key] = out
    return _DATA_CACHE[key]


# ------------------------------------------------------------------ registry

WORKLOADS: dict = {}


def register(workload: Workload, replace: bool = False) -> Workload:
    if not replace and workload.name in WORKLOADS:
        raise ValueError(f"workload {workload.name!r} already registered")
    WORKLOADS[workload.name] = workload
    return workload


def get(name: str) -> Workload:
    if name not in WORKLOADS:
        known = ", ".join(sorted(WORKLOADS))
        raise KeyError(f"unknown workload {name!r}; registered: {known}")
    return WORKLOADS[name]


def resolve(workload) -> Workload:
    """Accept a registry name or an ad-hoc Workload instance."""
    if isinstance(workload, Workload):
        return workload
    return get(workload)


def names() -> tuple:
    return tuple(sorted(WORKLOADS))


def _cfg(n, k, t, eta=1.0):
    return CopmlConfig(n_clients=n, k=k, t=t, eta=eta)


# reduced-scale: train for real on a CPU budget ---------------------------
register(Workload("smoke", m=96, d=12, cfg=_cfg(13, *case1_params(13)),
                  iters=10))
register(Workload("quickstart", m=260, d=16, cfg=_cfg(13, *case1_params(13)),
                  iters=30))
register(Workload("engine_micro", m=208, d=12,
                  cfg=_cfg(13, *case1_params(13)), seed=1, iters=20))
# shapes/margins match benchmarks/fig4_accuracy.py (paper Fig. 4 at
# reduced m with a held-out eval split)
register(Workload("cifar10_like", m=480, d=96, cfg=_cfg(15, *case2_params(15)),
                  seed=5, margin=1.2, test_m=160, iters=40))
register(Workload("gisette_like", m=480, d=128,
                  cfg=_cfg(15, *case2_params(15)), seed=5, margin=3.0,
                  test_m=160, iters=40))
# straggler demo: K=3, T=1 at N=13 leaves R=10 < N; decode from the LAST R
register(Workload("smoke_straggler", m=96, d=12, cfg=_cfg(13, 3, 1), iters=4,
                  subset=tuple(range(3, 13))))
# non-binary objectives: 10-class one-vs-rest on a (d, 10) field matrix
# (dataset encoded ONCE for all 10 classes -- the encode-once/class-batch
# path), and linear regression (ghat(z) = z exactly, r = 1)
register(Workload("mnist10_like", m=390, d=24, cfg=_cfg(13, *case1_params(13)),
                  seed=7, margin=3.0, test_m=130, iters=25,
                  objective=objectives.get("ovr10")))
register(Workload("linreg_smoke", m=96, d=12, cfg=_cfg(13, *case1_params(13)),
                  seed=3, iters=12, objective=objectives.LINREG))

def _field_safe_cfg(cfg: CopmlConfig, m: int, name: str) -> CopmlConfig:
    """Keep the paper's eta when the derived truncation depth fits the
    26-bit field; otherwise apply the documented eta-with-m scaling (the
    field-size scalability limit, same rule as copml_dist.make_config) so
    every registered workload is actually fittable."""
    try:
        derive_update_constants(cfg, m)
        return cfg
    except AssertionError:
        bumped = dataclasses.replace(cfg, eta=max(cfg.eta, m / 4096.0))
    try:
        derive_update_constants(bumped, m)
    except AssertionError as exc:
        raise ValueError(
            f"workload {name!r} (m={m}, cfg={cfg}) does not fit the 26-bit "
            f"field even after eta scaling to {bumped.eta}") from exc
    return bumped


# paper-scale: Section V-A shapes from configs/copml_logreg (data this size
# is only materialized if a fit actually asks for it)
for _w in copml_logreg.WORKLOADS.values():
    register(Workload(_w.name, m=_w.m, d=_w.d,
                      cfg=_field_safe_cfg(_w.cfg, _w.m, _w.name), iters=50))
