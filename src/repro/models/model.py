"""Model zoo: parameter tables, forward passes, losses for all families.

Params are a flat dict name -> array; per-layer params are stacked on a
leading n_layers axis and consumed by lax.scan (keeps the HLO O(1) in depth,
which is what makes the 512-device dry-run compiles tractable).  Every
parameter's PartitionSpec lives in the same table (sharding/partition.py
normalizes them to a concrete mesh).

Sharding convention (DESIGN.md section 5):
  batch                -> ("pod", "data")
  attn heads / d_ff /
  d_inner / experts    -> "model"          (TP / EP)
  vocab                -> "model"          (sharded logits + psum'd CE)
  decode KV cache seq  -> "model" (batch on "data"); long_500k (batch=1)
                          shards cache seq on "data" too
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import common, moe as moe_lib, ssm as ssm_lib
from .config import ModelConfig

BATCH = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class Par:
    shape: tuple
    spec: tuple
    init: str = "normal"      # normal | zeros | ones | alog | dtbias
    dtype: Optional[str] = None


# --------------------------------------------------------------------- table

def _attn_pars(cfg: ModelConfig, t: dict, prefix: str = "", kv: bool = True):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    t[prefix + "attn_norm"] = Par((d,), (None,), "ones")
    t[prefix + "wq"] = Par((d, hq * hd), (None, "model"))
    t[prefix + "wk"] = Par((d, hkv * hd), (None, "model"))
    t[prefix + "wv"] = Par((d, hkv * hd), (None, "model"))
    t[prefix + "wo"] = Par((hq * hd, d), ("model", None))
    if cfg.qkv_bias:
        t[prefix + "bq"] = Par((hq * hd,), ("model",), "zeros")
        t[prefix + "bk"] = Par((hkv * hd,), ("model",), "zeros")
        t[prefix + "bv"] = Par((hkv * hd,), ("model",), "zeros")
    if cfg.qk_norm:
        t[prefix + "q_norm"] = Par((hd,), (None,), "ones")
        t[prefix + "k_norm"] = Par((hd,), (None,), "ones")


def _mlp_pars(cfg: ModelConfig, t: dict, prefix: str = "", gelu: bool = False):
    d, ff = cfg.d_model, cfg.d_ff
    t[prefix + "mlp_norm"] = Par((d,), (None,), "ones")
    if gelu:
        t[prefix + "w_in"] = Par((d, ff), (None, "model"))
        t[prefix + "b_in"] = Par((ff,), ("model",), "zeros")
        t[prefix + "w_out"] = Par((ff, d), ("model", None))
        t[prefix + "b_out"] = Par((d,), (None,), "zeros")
    else:
        t[prefix + "w_gate"] = Par((d, ff), (None, "model"))
        t[prefix + "w_up"] = Par((d, ff), (None, "model"))
        t[prefix + "w_down"] = Par((ff, d), ("model", None))


def _mamba_pars(cfg: ModelConfig, t: dict, prefix: str = ""):
    d, di, ns = cfg.d_model, cfg.d_inner, cfg.ssm_state
    t[prefix + "ssm_norm"] = Par((d,), (None,), "ones")
    t[prefix + "in_proj"] = Par((d, 2 * di), (None, "model"))
    t[prefix + "conv_w"] = Par((cfg.ssm_conv, di), (None, "model"))
    t[prefix + "out_proj"] = Par((di, d), ("model", None))
    if cfg.ssm_version == 1:
        t[prefix + "x_proj"] = Par((di, cfg.dt_rank + 2 * ns), ("model", None))
        t[prefix + "dt_proj"] = Par((cfg.dt_rank, di), (None, "model"))
        t[prefix + "dt_bias"] = Par((di,), ("model",), "dtbias", "float32")
        t[prefix + "a_log"] = Par((di, ns), ("model", None), "alog", "float32")
        t[prefix + "dvec"] = Par((di,), ("model",), "ones")
    else:
        nh = cfg.mamba2_heads
        t[prefix + "b_proj"] = Par((d, ns), (None, None))
        t[prefix + "c_proj"] = Par((d, ns), (None, None))
        t[prefix + "dt_proj"] = Par((d, nh), (None, "model"))
        t[prefix + "dt_bias"] = Par((nh,), ("model",), "dtbias", "float32")
        t[prefix + "a_log"] = Par((nh,), ("model",), "alog", "float32")
        t[prefix + "dvec"] = Par((nh,), ("model",), "ones")


def _moe_pars(cfg: ModelConfig, t: dict, prefix: str = ""):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    t[prefix + "moe_norm"] = Par((d,), (None,), "ones")
    t[prefix + "router"] = Par((d, e), (None, None), dtype="float32")
    t[prefix + "w_gate"] = Par((e, d, ff), ("model", None, None))
    t[prefix + "w_up"] = Par((e, d, ff), ("model", None, None))
    t[prefix + "w_down"] = Par((e, ff, d), ("model", None, None))
    if cfg.dense_residual:
        t[prefix + "dense_w_gate"] = Par((d, ff), (None, "model"))
        t[prefix + "dense_w_up"] = Par((d, ff), (None, "model"))
        t[prefix + "dense_w_down"] = Par((ff, d), ("model", None))


def param_table(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    t: dict = {
        "embed": Par((cfg.vocab, d), ("model", None)),
        "final_norm": Par((d,), (None,), "ones"),
    }
    lt: dict = {}
    if cfg.family in ("dense", "vlm"):
        _attn_pars(cfg, lt)
        _mlp_pars(cfg, lt)
    elif cfg.family == "moe":
        _attn_pars(cfg, lt)
        _moe_pars(cfg, lt)
    elif cfg.family == "ssm":
        _mamba_pars(cfg, lt)
    elif cfg.family == "hybrid":
        _mamba_pars(cfg, lt)
        _attn_pars(cfg, t, "shared_attn/")      # ONE shared block (zamba2)
        _mlp_pars(cfg, t, "shared_attn/")
    elif cfg.family == "encdec":
        _attn_pars(cfg, lt)                      # decoder self-attn
        for nm in ("xq", "xk", "xv", "xo"):
            pass
        lt["xattn_norm"] = Par((d,), (None,), "ones")
        lt["xwq"] = Par((d, cfg.n_heads * cfg.hd), (None, "model"))
        lt["xwk"] = Par((d, cfg.n_kv * cfg.hd), (None, "model"))
        lt["xwv"] = Par((d, cfg.n_kv * cfg.hd), (None, "model"))
        lt["xwo"] = Par((cfg.n_heads * cfg.hd, d), ("model", None))
        _mlp_pars(cfg, lt, gelu=True)
        et: dict = {}
        _attn_pars(cfg, et)
        _mlp_pars(cfg, et, gelu=True)
        for k, v in et.items():
            t["enc_layers/" + k] = Par(
                (cfg.encoder_layers,) + v.shape, (None,) + v.spec, v.init,
                v.dtype)
        t["enc_norm"] = Par((d,), (None,), "ones")
    else:
        raise ValueError(cfg.family)
    if cfg.family == "vlm":
        t["patch_proj"] = Par((d, d), (None, None))
    for k, v in lt.items():
        t["layers/" + k] = Par((cfg.n_layers,) + v.shape, (None,) + v.spec,
                               v.init, v.dtype)
    return t


def init_params(cfg: ModelConfig, key) -> dict:
    table = param_table(cfg)
    out = {}
    for i, name in enumerate(sorted(table)):
        par = table[name]
        dt = jnp.dtype(par.dtype) if par.dtype else cfg.jdtype
        k = jax.random.fold_in(key, i)
        if par.init == "zeros":
            arr = jnp.zeros(par.shape, dt)
        elif par.init == "ones":
            arr = jnp.ones(par.shape, dt)
        elif par.init == "alog":
            ns = par.shape[-1]
            base = jnp.log(jnp.arange(1, ns + 1, dtype=jnp.float32))
            arr = jnp.broadcast_to(base, par.shape).astype(dt) \
                if ns > 1 else jnp.zeros(par.shape, dt)
        elif par.init == "dtbias":
            arr = jnp.full(par.shape, -2.0, dt)
        else:
            fan_in = par.shape[-2] if len(par.shape) >= 2 else par.shape[-1]
            arr = (jax.random.normal(k, par.shape, jnp.float32)
                   * (fan_in ** -0.5)).astype(dt)
        out[name] = arr
    return out


def param_specs(cfg: ModelConfig) -> dict:
    from jax.sharding import PartitionSpec as P
    return {k: P(*v.spec) for k, v in param_table(cfg).items()}


# ------------------------------------------------------------------- forward

def _attention(cfg, p, h, *, causal, cache=None, pos=None, prefix="",
               window=None, kv_input=None, q_offset: int = 0):
    """Returns (out, (k_new, v_new)) -- new cache entries when cache given,
    else the full-sequence K/V (for prefill)."""
    g = lambda nm: p[prefix + nm]
    b, s, d = h.shape
    x = common.rms_norm(h, g("attn_norm"), cfg.norm_eps)
    src = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,de->bse", x, g("wq"))
    k = jnp.einsum("bsd,de->bse", src, g("wk"))
    v = jnp.einsum("bsd,de->bse", src, g("wv"))
    if cfg.qkv_bias:
        q, k, v = q + g("bq"), k + g("bk"), v + g("bv")
    q = q.reshape(b, s, cfg.n_heads, cfg.hd)
    k = k.reshape(b, src.shape[1], cfg.n_kv, cfg.hd)
    v = v.reshape(b, src.shape[1], cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        q = common.rms_norm(q, g("q_norm"), cfg.norm_eps)
        k = common.rms_norm(k, g("k_norm"), cfg.norm_eps)
    if kv_input is None and cfg.family != "encdec":   # self-attn: rope
        # (whisper uses absolute sinusoidal positions added to h instead)
        q = common.rope(q, q_offset + jnp.arange(s)[None], cfg.rope_theta)
        if cache is None:
            k = common.rope(k, jnp.arange(src.shape[1])[None], cfg.rope_theta)
        else:
            k = common.rope(k, (q_offset + jnp.arange(s))[None],
                            cfg.rope_theta)

    if cache is not None:                      # decode: update + attend
        k_cache, v_cache = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=1)
        out = common.decode_attention(q, k_cache, v_cache, pos + s)
        new_kv = (k_cache, v_cache)
    else:
        out = common.flash_attention(q, k, v, causal=causal, window=window,
                                     q_offset=q_offset)
        new_kv = (k, v)
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), g("wo"))
    return out, new_kv


def _mlp(cfg, p, h, prefix="", gelu=False):
    x = common.rms_norm(h, p[prefix + "mlp_norm"], cfg.norm_eps)
    if gelu:
        return common.gelu_mlp(x, p[prefix + "w_in"], p[prefix + "b_in"],
                               p[prefix + "w_out"], p[prefix + "b_out"])
    return common.swiglu(x, p[prefix + "w_gate"], p[prefix + "w_up"],
                         p[prefix + "w_down"])


def _layer(cfg: ModelConfig, params_all, p, h, cache, pos, layer_idx,
           window=None):
    """One decoder layer of any family.  Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    qo = 0 if pos is None else pos          # decode: rope at the true position
    if cfg.family in ("dense", "vlm"):
        a, kv = _attention(cfg, p, h, causal=True, cache=cache, pos=pos,
                           window=window, q_offset=qo)
        h = h + a
        h = h + _mlp(cfg, p, h)
        return h, kv, aux
    if cfg.family == "moe":
        a, kv = _attention(cfg, p, h, causal=True, cache=cache, pos=pos,
                           window=window, q_offset=qo)
        h = h + a
        x = common.rms_norm(h, p["moe_norm"], cfg.norm_eps)
        mo, aux = moe_lib.moe_forward(
            {"router": p["router"], "w_gate": p["w_gate"],
             "w_up": p["w_up"], "w_down": p["w_down"]}, x, cfg)
        if cfg.dense_residual:
            mo = mo + common.swiglu(x, p["dense_w_gate"], p["dense_w_up"],
                                    p["dense_w_down"])
        return h + mo, kv, aux
    if cfg.family in ("ssm", "hybrid"):
        x = common.rms_norm(h, p["ssm_norm"], cfg.norm_eps)
        fwd = ssm_lib.mamba1_forward if cfg.ssm_version == 1 \
            else ssm_lib.mamba2_forward
        out, new_cache = fwd(p, x, cfg, cache)
        return h + out, new_cache, aux
    raise ValueError(cfg.family)


def _layer_params(params: dict, prefix: str = "layers/") -> dict:
    return {k[len(prefix):]: v for k, v in params.items()
            if k.startswith(prefix)}


def _embed_tokens(params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def logits_from_h(params, h):
    return jnp.einsum("bsd,vd->bsv", h, params["embed"])


def encode_frames(cfg, params, frames):
    """Whisper encoder over STUB frame embeddings (B, Se, d)."""
    pos = _sinusoid(cfg, frames.shape[1]).astype(frames.dtype)
    h = frames + pos[None]
    lp = _layer_params(params, "enc_layers/")

    def body(h, p):
        a, _ = _attention(cfg, p, h, causal=False)
        h = h + a
        h = h + _mlp(cfg, p, h, gelu=True)
        return h, None

    fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(fn, h, lp)
    return common.rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _sinusoid(cfg, s):
    d = cfg.d_model
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def forward(cfg: ModelConfig, params: dict, tokens, *,
            frontier=None, caches=None, pos=None, collect_cache=False):
    """Full forward.  tokens: (B, S) int32.

    frontier: modality input -- whisper frames (B,Se,d) / vlm patches
    (B,Np,d) / None.  caches: decode caches pytree or None.
    pos: decode position (int scalar) or None.
    Returns (hidden (B,S,d), new_caches or per-layer prefill cache, aux).
    """
    h = _embed_tokens(params, tokens).astype(cfg.jdtype)
    q_offset = 0 if pos is None else pos
    n_prefix = 0
    if cfg.family == "vlm" and frontier is not None:
        patches = jnp.einsum("bpd,de->bpe", frontier.astype(cfg.jdtype),
                             params["patch_proj"])
        h = jnp.concatenate([patches, h], axis=1)
        n_prefix = frontier.shape[1]
    if cfg.family == "encdec":
        if pos is None:
            h = h + _sinusoid(cfg, h.shape[1])[None].astype(h.dtype)
        else:                         # decode: absolute position of the token
            table_len = jax.tree_util.tree_leaves(caches)[0].shape[2] \
                if caches is not None else h.shape[1]
            table = _sinusoid(cfg, table_len).astype(h.dtype)
            h = h + jax.lax.dynamic_slice_in_dim(
                table, pos, h.shape[1], axis=0)[None]
        enc_out = (encode_frames(cfg, params, frontier)
                   if frontier is not None else None)

    lp = _layer_params(params)
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.family == "hybrid" and cfg.attn_every:
        # zamba2: groups of `attn_every` mamba2 layers + ONE shared attention
        # block applied between groups (shared weights across applications)
        groups = cfg.n_layers // cfg.attn_every
        lp = jax.tree.map(
            lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]), lp)
        shared = {k[len("shared_attn/"):]: v for k, v in params.items()
                  if k.startswith("shared_attn/")}
        m_caches, a_caches = (None, None) if caches is None else caches
        new_m, new_a = [], []

        def inner(h, xs):
            p, c = xs
            h, nc, _ = _layer(cfg, params, p, h, c, pos, 0)
            return h, nc

        inner_fn = jax.checkpoint(inner) if cfg.remat else inner
        for gi in range(groups):
            gp = jax.tree.map(lambda a: a[gi], lp)
            gc = None if m_caches is None else jax.tree.map(
                lambda a: a[gi], m_caches)
            h, nc = jax.lax.scan(inner_fn, h, (gp, gc))
            new_m.append(nc)
            ac = None if a_caches is None else jax.tree.map(
                lambda a: a[gi], a_caches)
            a, akv = _attention(cfg, shared, h, causal=True, cache=ac,
                                pos=pos, window=cfg.window,
                                q_offset=q_offset)
            h = h + a
            h = h + _mlp(cfg, shared, h)
            new_a.append(akv)
        new_caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                      jax.tree.map(lambda *xs: jnp.stack(xs), *new_a))
        h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
        return h, new_caches, aux_total

    def body(carry, xs):
        h, aux = carry
        p, c = xs
        h, nc, a = _layer(cfg, params, p, h, c, pos, 0, window=cfg.window)
        return (h, aux + a), nc

    if cfg.family == "encdec":
        # cross-attn K/V from encoder output: computed per layer inside scan
        # via kv_input = enc_out (weights differ per layer, so pass enc_out)
        def body(carry, xs):     # noqa: F811  (encdec-specialized)
            h, aux = carry
            p, c = xs
            a, kv = _attention(cfg, p, h, causal=True,
                               cache=None if c is None else (c[0], c[1]),
                               pos=pos, q_offset=q_offset)
            h = h + a
            if c is None:
                xa, xkv = _attention(cfg, p, h, causal=False, prefix="x",
                                     kv_input=enc_out)
            else:
                xa = common.decode_attention(
                    jnp.einsum("bsd,de->bse", common.rms_norm(
                        h, p["xattn_norm"], cfg.norm_eps), p["xwq"]
                    ).reshape(h.shape[0], h.shape[1], cfg.n_heads, cfg.hd),
                    c[2], c[3], c[2].shape[1])
                xa = jnp.einsum("bse,ed->bsd",
                                xa.reshape(h.shape[0], h.shape[1], -1),
                                p["xwo"])
            h = h + xa
            h = h + _mlp(cfg, p, h, gelu=True)
            if c is None:
                nc = (kv[0], kv[1], xkv[0], xkv[1])   # prefill: self + cross
            else:
                nc = (kv[0], kv[1], c[2], c[3])
            return (h, aux), nc

    fn = jax.checkpoint(body) if (cfg.remat and caches is None) else body
    (h, aux_total), new_caches = jax.lax.scan(
        fn, (h, aux_total), (lp, caches))
    h = common.rms_norm(h, params["final_norm"], cfg.norm_eps)
    if n_prefix:
        h = h[:, n_prefix:]
    return h, new_caches, aux_total
