"""Mixture-of-experts layer: top-k routing with sort-based capacity dispatch.

The (E, C, d) expert buffer is sharded on the 'model' axis (expert
parallelism); tokens are sharded on 'data', so the scatter into the buffer
and the gather back lower to all-to-all-style collectives under GSPMD --
exactly the EP communication pattern the roofline's collective term prices.

Memory is O(E*C*d + T*k*d); no (T, E, C) one-hot tensor is ever built
(that would be ~10^13 elements at the assigned shapes).  Overflowing tokens
beyond capacity are dropped (standard "dropping" MoE); an aux load-balance
loss keeps the router near-uniform.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp




def moe_forward(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    p keys: router (d, E), w_gate/w_up (E, d, ff), w_down (E, ff, d).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, k)                  # (T, k)
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.mean(
        jax.nn.one_hot(gate_i[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(density * jnp.mean(probs, axis=0))

    # --- cumsum-based capacity dispatch (NO global sort: a sharded argsort
    #     under GSPMD all-gathers the whole token stream; the prefix-sum
    #     formulation shards cleanly along T) ---
    cap = max(1, int(cfg.capacity_factor * t * k / e))
    # tiny token counts (decode steps): don't drop below a few slots per
    # expert or single-token batches lose routed experts entirely
    cap = max(cap, min(t * k, 4))
    oh = jax.nn.one_hot(gate_i, e, dtype=jnp.int32)           # (T, k, E)
    oh_tok = jnp.sum(oh, axis=1)                              # (T, E)
    csum = jnp.cumsum(oh_tok, axis=0) - oh_tok                # exclusive (T,E)
    intra = jnp.cumsum(oh, axis=1) - oh                       # within-token
    pos = jnp.take_along_axis(csum[:, None, :] + intra,
                              gate_i[..., None], axis=2)[..., 0]   # (T, k)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, 0)

    # Expert-buffer sharding is left to GSPMD: both measured alternatives
    # lose (EXPERIMENTS.md section Perf, MoE cell) -- explicit "model"
    # constraints trade -17% collective for +66% peak HBM (over budget);
    # sharding capacity over "data" removes the 16x duplicated expert
    # compute but makes the scatter collective-pathological (~16x more wire
    # bytes).  The real fix is a shard_map ragged all-to-all dispatch
    # (documented next step).
    buf = jnp.zeros((e, cap, d), x.dtype)
    upd = jnp.where(keep[..., None], xf[:, None, :], 0).astype(x.dtype)
    buf = buf.at[gate_i, pos_c].add(upd)                      # (E, C, d)

    h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, p["w_down"])

    gathered = y[gate_i, pos_c]                               # (T, k, d)
    out = jnp.sum(gathered *
                  jnp.where(keep, gate_w, 0.0)[..., None].astype(x.dtype),
                  axis=1)
    return out.reshape(b, s, d), aux
