"""Selective state-space blocks: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Training/prefill uses an outer lax.scan over time-chunks with a rematerialized
chunk body (only chunk-boundary states are stored for backward) and an inner
lax.scan over steps -- no (S, d_inner, state) tensor is ever materialized.
Decode carries (conv_state, ssm_state) and is O(1) in context length: this is
why the ssm/hybrid archs run the long_500k shape (DESIGN.md section 6).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

TIME_CHUNK = 128


def _causal_conv(x, w, cache: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over time.  x: (B, S, C); w: (K, C).

    cache: (B, K-1, C) previous inputs for decode continuity.
    Returns (y (B, S, C), new_cache (B, K-1, C)).
    """
    k = w.shape[0]
    if cache is None:
        cache = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([cache, x], axis=1)
    y = jnp.zeros_like(x)
    for i in range(k):
        y = y + xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
    new_cache = xp[:, -(k - 1):, :] if k > 1 else cache
    return y, new_cache


def _ssm_scan(decay, inp, h0, chunk: int):
    """h_t = decay_t * h_{t-1} + inp_t, scanned over axis 1 (time).

    decay, inp: (B, S, ...state dims);  h0: (B, ...).  Returns (ys, h_S).
    Outer scan over S/chunk with checkpointed body, inner scan over steps.
    """
    b, s = inp.shape[:2]
    chunk = min(chunk, s)
    n = -(-s // chunk)
    pad = n * chunk - s
    if pad:
        decay = jnp.pad(decay, ((0, 0), (0, pad)) + ((0, 0),) * (decay.ndim - 2),
                        constant_values=1.0)
        inp = jnp.pad(inp, ((0, 0), (0, pad)) + ((0, 0),) * (inp.ndim - 2))
    dc = jnp.moveaxis(decay.reshape((b, n, chunk) + decay.shape[2:]), 1, 0)
    ic = jnp.moveaxis(inp.reshape((b, n, chunk) + inp.shape[2:]), 1, 0)

    @jax.checkpoint
    def chunk_body(h, xs):
        d_blk, i_blk = xs              # (B, chunk, ...)

        def step(hh, t):
            d_t, i_t = t
            hh = d_t * hh + i_t
            return hh, hh

        h, ys = jax.lax.scan(
            step, h, (jnp.moveaxis(d_blk, 1, 0), jnp.moveaxis(i_blk, 1, 0)))
        return h, jnp.moveaxis(ys, 0, 1)   # (B, chunk, ...)

    h, ys = jax.lax.scan(chunk_body, h0, (dc, ic))
    ys = jnp.moveaxis(ys, 0, 1).reshape((b, n * chunk) + inp.shape[2:])
    return ys[:, :s], h


def mamba1_forward(p, x, cfg, cache=None):
    """Mamba1 block.  x: (B, S, d_model).  cache: None or (conv, h).

    p keys: in_proj (d, 2di), conv_w (K, di), x_proj (di, dt_rank+2N),
    dt_proj (dt_rank, di), dt_bias (di,), a_log (di, N), dvec (di,),
    out_proj (di, d).
    """
    di, ns = cfg.d_inner, cfg.ssm_state
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_cache = cache[0] if cache is not None else None
    xin, new_conv = _causal_conv(xin, p["conv_w"], conv_cache)
    xin = jax.nn.silu(xin)

    proj = jnp.einsum("bse,ef->bsf", xin, p["x_proj"])
    dt_low, bmat, cmat = jnp.split(
        proj, [cfg.dt_rank, cfg.dt_rank + ns], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,re->bse", dt_low, p["dt_proj"]) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (di, N)
    decay = jnp.exp(dt.astype(jnp.float32)[..., None] * a)   # (B,S,di,N)
    inp = (dt * xin).astype(jnp.float32)[..., None] * \
        bmat.astype(jnp.float32)[..., None, :]               # (B,S,di,N)

    h0 = cache[1] if cache is not None else \
        jnp.zeros((x.shape[0], di, ns), jnp.float32)
    hs, h_last = _ssm_scan(decay, inp, h0, TIME_CHUNK)
    y = jnp.einsum("bsen,bsn->bse", hs, cmat.astype(jnp.float32))
    y = y.astype(x.dtype) + xin * p["dvec"]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, (new_conv, h_last)


def _mamba2_proj(p, x, cfg, cache):
    """Shared projections for both mamba2 execution paths."""
    di, ns, nh = cfg.d_inner, cfg.ssm_state, cfg.mamba2_heads
    hd = di // nh
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_cache = cache[0] if cache is not None else None
    xin, new_conv = _causal_conv(xin, p["conv_w"], conv_cache)
    xin = jax.nn.silu(xin)
    xh = xin.reshape(xin.shape[0], xin.shape[1], nh, hd)      # (B,S,nh,hd)
    bmat = jnp.einsum("bsd,dn->bsn", x, p["b_proj"]).astype(jnp.float32)
    cmat = jnp.einsum("bsd,dn->bsn", x, p["c_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["dt_proj"]) + p["dt_bias"]
    ).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))              # (nh,)
    return xh, z, bmat, cmat, dt, a, new_conv


def _mamba2_finish(p, x, xh, z, y, cfg):
    y = y.astype(x.dtype) + xh * p["dvec"][None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], -1) * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"])


def mamba2_forward_scan(p, x, cfg, cache=None):
    """Mamba2 reference path: explicit state recurrence (decode + oracle).

    Materializes (B,S,nh,hd,ns) decay/input tensors -- fine for S=1 decode,
    prohibitive HBM traffic for training (see mamba2_forward)."""
    ns, nh = cfg.ssm_state, cfg.mamba2_heads
    hd = cfg.d_inner // nh
    xh, z, bmat, cmat, dt, a, new_conv = _mamba2_proj(p, x, cfg, cache)
    decay = jnp.exp(dt * a)                                   # (B,S,nh)
    decay = jnp.broadcast_to(decay[..., None, None],
                             decay.shape + (hd, ns))
    inp = (dt[..., None] * xh.astype(jnp.float32))[..., None] * \
        bmat[..., None, None, :]                              # (B,S,nh,hd,N)
    h0 = cache[1] if cache is not None else \
        jnp.zeros((x.shape[0], nh, hd, ns), jnp.float32)
    hs, h_last = _ssm_scan(decay, inp, h0, TIME_CHUNK)
    y = jnp.einsum("bshpn,bsn->bshp", hs, cmat)
    out = _mamba2_finish(p, x, xh, z, y, cfg)
    return out, (new_conv, h_last)


def mamba2_forward(p, x, cfg, cache=None, chunk: int = 128):
    """Mamba2 block via the SSD chunked-matmul algorithm (training path).

    The naive recurrence materializes (B,S,nh,hd,ns) decay/input tensors --
    at zamba2's train_4k shard that is ~0.7 GB *per layer per pass*, and it
    runs on the VPU.  SSD turns the same recurrence into chunk-local
    (c x c) score matmuls (MXU) + an S/c-step state scan, shrinking HBM
    traffic ~a/x40 and moving the flops to the MXU (EXPERIMENTS.md
    section Perf, zamba2 cell).  Exact: equals mamba2_forward_scan to f32
    tolerance (tests/test_models.py::test_mamba2_ssd_matches_scan).
    """
    if x.shape[1] == 1:                       # decode: one recurrence step
        return mamba2_forward_scan(p, x, cfg, cache)
    ns, nh = cfg.ssm_state, cfg.mamba2_heads
    hd = cfg.d_inner // nh
    xh, z, bmat, cmat, dt, a, new_conv = _mamba2_proj(p, x, cfg, cache)
    b, s = x.shape[0], x.shape[1]
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        padf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) *
                                 (t.ndim - 2))
        xhp, bp, cp, dtp = map(padf, (xh.astype(jnp.float32), bmat, cmat,
                                      dt))
    else:
        xhp, bp, cp, dtp = xh.astype(jnp.float32), bmat, cmat, dt
    nc = (s + pad) // c
    shp = lambda t: t.reshape((b, nc, c) + t.shape[2:])
    xc, bc, cc, dtc = map(shp, (xhp, bp, cp, dtp))
    loga = dtc * a                                            # (B,nc,c,nh)
    la = jnp.cumsum(loga, axis=2)                             # inclusive
    bx = dtc[..., None] * xc                                  # (B,nc,c,nh,hd)

    # intra-chunk: y[i] += sum_{j<=i} exp(la_i - la_j) (C_i.B_j) bx_j
    cb = jnp.einsum("bkin,bkjn->bkij", cc, bc)                # (B,nc,c,c)
    diff = la[:, :, :, None, :] - la[:, :, None, :, :]        # (B,nc,i,j,nh)
    causal = jnp.tril(jnp.ones((c, c), bool))
    scores = jnp.where(causal[None, None, :, :, None],
                       jnp.exp(diff), 0.0) * cb[..., None]    # (B,nc,i,j,nh)
    y_intra = jnp.einsum("bkijh,bkjhp->bkihp", scores, bx)

    # per-chunk state contribution + inter-chunk recurrence
    dec_end = jnp.exp(la[:, :, -1:, :] - la)                  # (B,nc,c,nh)
    s_k = jnp.einsum("bkjh,bkjhp,bkjn->bkhpn", dec_end, bx, bc)
    a_k = jnp.exp(la[:, :, -1, :])                            # (B,nc,nh)
    h0 = cache[1] if cache is not None else \
        jnp.zeros((b, nh, hd, ns), jnp.float32)

    def step(h, inputs):
        ak, sk = inputs                                       # (B,nh), (B,nh,hd,ns)
        h_new = ak[..., None, None] * h + sk
        return h_new, h                                       # emit h_prev

    h_last, h_prevs = jax.lax.scan(
        step, h0, (jnp.moveaxis(a_k, 1, 0), jnp.moveaxis(s_k, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # (B,nc,nh,hd,ns)
    y_inter = jnp.einsum("bkih,bkin,bkhpn->bkihp",
                         jnp.exp(la), cc, h_prevs)
    y = (y_intra + y_inter).reshape(b, nc * c, nh, hd)[:, :s]
    out = _mamba2_finish(p, x, xh, z, y, cfg)
    return out, (new_conv, h_last)


def ssm_decode_cache(cfg, batch: int, dtype):
    """Zero cache for one layer: (conv_state, ssm_state)."""
    di = cfg.d_inner
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype)
    if cfg.ssm_version == 1:
        h = jnp.zeros((batch, di, cfg.ssm_state), jnp.float32)
    else:
        nh = cfg.mamba2_heads
        h = jnp.zeros((batch, nh, di // nh, cfg.ssm_state), jnp.float32)
    return conv, h
