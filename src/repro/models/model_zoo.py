"""Public model API: build(cfg) -> steps + input specs + cache init.

Everything here is shape-polymorphic over (batch, seq) and mesh-agnostic;
launch/dryrun.py and train/trainer.py add pjit shardings on top.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import model as M
from . import ssm as ssm_lib
from .config import ModelConfig, ShapeConfig
from ..optim import optimizers

LOSS_CHUNK = 0            # 0 = full logits; >0 = seq-chunked CE (section Perf)
BATCH_AXES = ("pod", "data")


from .common import maybe_constrain as _maybe_constrain  # noqa: E402


def cross_entropy(params, h, labels, mask, *, chunk: int = 0):
    """Next-token CE from hidden states, optionally chunked over seq.

    Chunking never materializes the full (B, S, V) logits -- the memory-term
    optimization recorded in EXPERIMENTS.md section Perf.
    """
    if chunk and h.shape[1] > chunk and h.shape[1] % chunk == 0:
        b, s, d = h.shape
        n = s // chunk
        hc = jnp.moveaxis(h.reshape(b, n, chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)
        mc = jnp.moveaxis(mask.reshape(b, n, chunk), 1, 0)

        def body(carry, xs):
            hh, ll, mm = xs
            num, den = _ce_chunk(params, hh, ll, mm)
            return (carry[0] + num, carry[1] + den), None

        (num, den), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
            (hc, lc, mc))
        return num / jnp.maximum(den, 1.0)
    num, den = _ce_chunk(params, h, labels, mask)
    return num / jnp.maximum(den, 1.0)


def _ce_chunk(params, h, labels, mask):
    logits = M.logits_from_h(params, h).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return jnp.sum(nll), jnp.sum(mask)


def _frontier_shape(cfg: ModelConfig, batch: int):
    if cfg.family == "encdec":
        return (batch, cfg.encoder_seq, cfg.d_model)
    if cfg.family == "vlm":
        return (batch, cfg.n_patches, cfg.d_model)
    return None


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (dry-run)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out = {}
    if shape.kind == "train":
        out["tokens"] = sds((b, s), jnp.int32)
        out["labels"] = sds((b, s), jnp.int32)
        out["mask"] = sds((b, s), jnp.float32)
    elif shape.kind == "prefill":
        out["tokens"] = sds((b, s), jnp.int32)
    else:                                        # decode: one new token
        out["tokens"] = sds((b, 1), jnp.int32)
    fs = _frontier_shape(cfg, b)
    if fs is not None and shape.kind != "decode":
        out["frontier"] = sds(fs, cfg.jdtype)
    return out


# ---------------------------------------------------------------- cache init

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False):
    """Decode caches (zeros or ShapeDtypeStructs)."""
    mk = (lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)) if abstract \
        else (lambda shape, dt: jnp.zeros(shape, dt))
    L, hkv, hd, dt = cfg.n_layers, cfg.n_kv, cfg.hd, cfg.jdtype
    if cfg.family in ("dense", "vlm", "moe"):
        return (mk((L, batch, max_seq, hkv, hd), dt),
                mk((L, batch, max_seq, hkv, hd), dt))
    if cfg.family == "ssm":
        conv = mk((L, batch, cfg.ssm_conv - 1, cfg.d_inner), dt)
        h = mk((L, batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
        return (conv, h)
    if cfg.family == "hybrid":
        g, a = cfg.n_layers // cfg.attn_every, cfg.attn_every
        conv = mk((g, a, batch, cfg.ssm_conv - 1, cfg.d_inner), dt)
        nh = cfg.mamba2_heads
        h = mk((g, a, batch, nh, cfg.d_inner // nh, cfg.ssm_state),
               jnp.float32)
        kv = (mk((g, batch, max_seq, hkv, hd), dt),
              mk((g, batch, max_seq, hkv, hd), dt))
        return ((conv, h), kv)
    if cfg.family == "encdec":
        return (mk((L, batch, max_seq, hkv, hd), dt),
                mk((L, batch, max_seq, hkv, hd), dt),
                mk((L, batch, cfg.encoder_seq, hkv, hd), dt),
                mk((L, batch, cfg.encoder_seq, hkv, hd), dt))
    raise ValueError(cfg.family)


# --------------------------------------------------------------------- steps

@dataclasses.dataclass(frozen=True)
class BuiltModel:
    cfg: ModelConfig
    init_params: Any
    train_step: Any
    prefill_step: Any
    decode_step: Any
    loss_fn: Any


def build(cfg: ModelConfig, opt_cfg: Optional[optimizers.OptConfig] = None,
          microbatch: int = 0, loss_chunk: int = LOSS_CHUNK,
          secure_agg_cfg=None) -> BuiltModel:
    opt = optimizers.make(cfg.optimizer, opt_cfg)

    def loss_fn(params, batch):
        h, _, aux = M.forward(cfg, params, batch["tokens"],
                              frontier=batch.get("frontier"))
        loss = cross_entropy(params, h, batch["labels"], batch["mask"],
                             chunk=loss_chunk)
        return loss + 0.01 * aux, loss

    def grad_fn(params, batch):
        (tot, loss), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, loss

    def train_step(params, opt_state, batch, step):
        if microbatch and batch["tokens"].shape[0] > microbatch:
            b = batch["tokens"].shape[0]
            n = b // microbatch
            # re-shard each microbatch across the data axes: without the
            # constraint GSPMD half-shards the (n, mb) reshape and every
            # microbatch step sees the full per-device batch (EXPERIMENTS.md
            # section Perf, memory term)
            mb = jax.tree.map(
                lambda x: _maybe_constrain(
                    x.reshape((n, microbatch) + x.shape[1:]),
                    None, BATCH_AXES), batch)

            def body(carry, xs):
                g_acc, l_acc = carry
                g, l = grad_fn(params, xs)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss / n
        else:
            grads, loss = grad_fn(params, batch)
        if secure_agg_cfg is not None:
            # beyond-paper hook: COPML-coded secure gradient aggregation
            # across the data axis (core/secure_agg.py); wired by the
            # trainer under shard_map.  Single-process path is identity.
            pass
        new_params, new_opt, gnorm = opt.update(grads, opt_state, params,
                                                step)
        return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    def prefill_step(params, batch):
        """Forward pass producing logits + decode caches."""
        tokens = batch["tokens"]
        h, caches, _ = M.forward(cfg, params, tokens,
                                 frontier=batch.get("frontier"))
        logits = M.logits_from_h(params, h[:, -1:])
        return logits, caches

    def decode_step(params, caches, tokens, pos):
        """One new token against the caches at position pos."""
        h, new_caches, _ = M.forward(cfg, params, tokens, caches=caches,
                                     pos=pos)
        logits = M.logits_from_h(params, h)
        return logits, new_caches

    return BuiltModel(
        cfg=cfg,
        init_params=functools.partial(M.init_params, cfg),
        train_step=train_step,
        prefill_step=prefill_step,
        decode_step=decode_step,
        loss_fn=loss_fn,
    )
