"""Shared transformer building blocks (pure JAX, shard-friendly).

Attention is implemented flash-style: an online-softmax scan over KV chunks,
so prefill at 32k context never materializes the (S, S) score matrix.  All
ops are dtype-explicit (bf16 compute, f32 softmax statistics and norms).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from ..core.meshutil import maybe_constrain  # noqa: F401  (re-export)

DEFAULT_KV_CHUNK = 1024


def rms_norm(x, weight, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.exp(
        -jnp.arange(0, half, dtype=jnp.float32) * (jnp.log(theta) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(x.dtype)


def _chunk_attn(q, k, v, mask, scale):
    """One KV chunk: q (B,Sq,Hk,G,hd), k/v (B,C,Hk,hd), mask (Sq,C) or None.

    Returns (scores_max (B,Sq,Hk,G), exp-sum, weighted-V partial) in f32.
    """
    s = jnp.einsum("bqkgh,bckh->bqkgc", q, k,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    # guard fully-masked rows
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(s - m_safe[..., None])
    p = jnp.where(jnp.isfinite(s), p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bqkgc,bckh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return m_safe, l, o


def flash_attention(q, k, v, *, causal: bool,
                    window: Optional[int] = None,
                    q_offset: int = 0,
                    kv_chunk: int = DEFAULT_KV_CHUNK):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, Hq, hd);  k, v: (B, Skv, Hkv, hd);  GQA via head grouping.
    q_offset: absolute position of q[0] (decode: Skv-1 typically).
    Never materializes (Sq, Skv); peak transient is (B, Sq, Hq, kv_chunk).
    """
    b, sq, hq, hd = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b, sq, hkv, g, hd)
    scale = 1.0 / (hd ** 0.5)
    kv_chunk = min(kv_chunk, skv)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, kv_chunk, hkv, hd)
    vc = v.reshape(b, n_chunks, kv_chunk, hkv, hd)
    q_pos = q_offset + jnp.arange(sq)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, xs):
        m_run, l_run, o_run = carry
        idx, k_blk, v_blk = xs
        kv_pos = idx * kv_chunk + jnp.arange(kv_chunk)
        mask = jnp.ones((sq, kv_chunk), bool)
        mask &= (kv_pos[None, :] < skv)                      # padding
        if causal:
            mask &= (kv_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask &= (kv_pos[None, :] > q_pos[:, None] - window)
        m_new, l_new, o_new = _chunk_attn(qg, k_blk, v_blk, mask, scale)
        m = jnp.maximum(m_run, m_new)
        a = jnp.exp(m_run - m)
        bfac = jnp.exp(m_new - m)
        l = l_run * a + l_new * bfac
        o = o_run * a[..., None] + o_new * bfac[..., None]
        return (m, l, o), None

    m0 = jnp.full((b, sq, hkv, g), -jnp.inf, jnp.float32)
    # exp(-inf - -inf) guarded by starting m at a large negative finite
    m0 = jnp.full((b, sq, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    o0 = jnp.zeros((b, sq, hkv, g, hd), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        body, (m0, l0, o0),
        (jnp.arange(n_chunks), jnp.swapaxes(kc, 0, 1), jnp.swapaxes(vc, 0, 1)))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, hq, hd).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length):
    """Single-position attention against a (possibly overlong) cache.

    q: (B, 1, Hq, hd); caches: (B, Smax, Hkv, hd); length: valid prefix.
    """
    b, _, hq, hd = q.shape
    _, smax, hkv, _ = k_cache.shape
    g = hq // hkv
    qg = q.reshape(b, hkv, g, hd)
    scale = 1.0 / (hd ** 0.5)
    s = jnp.einsum("bkgh,bckh->bkgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(smax)
    s = jnp.where(pos[None, None, None, :] < length, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgc,bckh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, hq, hd).astype(q.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("bsd,df->bsf", x, w_gate)
    u = jnp.einsum("bsd,df->bsf", x, w_up)
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u, w_down)


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, w_in) + b_in)
    return jnp.einsum("bsf,fd->bsd", h, w_out) + b_out
