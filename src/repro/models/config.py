"""Architecture configuration for the assigned model zoo."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default d_model // n_heads
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2.5
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    dense_residual: bool = False            # arctic: parallel dense FFN branch
    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1                    # 1 = mamba1, 2 = mamba2 (SSD)
    ssm_heads: int = 0                      # mamba2 heads (0 => derived)
    # --- hybrid (zamba2): one SHARED attention block applied every
    #     attn_every ssm layers (weight sharing is the zamba2 design) ---
    attn_every: int = 0
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0                    # precomputed frame embeddings (stub)
    # --- vlm (internvl) ---
    n_patches: int = 0                      # precomputed patch embeddings (stub)
    # --- common ---
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    window: Optional[int] = None            # sliding-window attention
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    optimizer: str = "adamw"                # adamw | adafactor | sgdm
    # long-context applicability (DESIGN.md section 6)
    subquadratic: bool = False              # can run long_500k

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))

    @property
    def mamba2_heads(self) -> int:
        return self.ssm_heads or max(1, self.d_inner // 64)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced config of the same family (for CPU smoke tests)."""
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + layers)."""
        d, hd = self.d_model, self.hd
        attn = d * hd * (self.n_heads + 2 * self.n_kv) + self.n_heads * hd * d
        if self.qkv_bias:
            attn += hd * (self.n_heads + 2 * self.n_kv)
        mlp = 3 * d * self.d_ff
        per_layer = 0
        if self.family in ("dense", "vlm", "encdec"):
            per_layer = attn + mlp
        elif self.family == "moe":
            moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
            per_layer = attn + moe + (3 * d * self.d_ff if self.dense_residual else 0)
        elif self.family == "ssm":
            di, ns = self.d_inner, self.ssm_state
            per_layer = d * 2 * di + di * self.ssm_conv + \
                di * (self.dt_rank + 2 * ns) + self.dt_rank * di + di * d + di * ns
        elif self.family == "hybrid":
            di, ns = self.d_inner, self.ssm_state
            nh = self.mamba2_heads
            per_layer = d * (2 * di + 2 * ns + nh) + di * self.ssm_conv + di * d
        total = self.n_layers * per_layer + self.vocab * d
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp)
        if self.family == "hybrid" and self.attn_every:
            total += attn + mlp                     # one shared block
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top_k of n_experts)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        full = self.param_count()
        moe_all = self.n_layers * self.n_experts * 3 * d * self.d_ff
        moe_active = self.n_layers * self.top_k * 3 * d * self.d_ff
        return full - moe_all + moe_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def applicable_shapes(cfg: ModelConfig):
    """long_500k needs sub-quadratic attention (DESIGN.md section 6)."""
    return tuple(s for s in ALL_SHAPES
                 if s.name != "long_500k" or cfg.subquadratic)
