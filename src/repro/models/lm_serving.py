"""Batched decode serving driver: prefill once, decode autoregressively.

Greedy decoding with a fixed-size cache (the decode_32k / long_500k shapes);
the decode step is the same jitted function the dry-run lowers, so measured
serving behaviour and the roofline analysis describe the same program.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import model_zoo as MZ
from .config import ModelConfig


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 16
    cache_len: int = 256
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0


def _copy_prefill_into_cache(cfg, prefill_caches, caches, prompt_len):
    """Write the prefill-produced K/V (seq = prompt_len) into the serving
    cache (seq = cache_len) at offset 0."""
    def place(full, pref):
        if full.shape == pref.shape:
            return pref
        # same rank; the (only) differing dim is the sequence dim
        for ax, (a, b) in enumerate(zip(full.shape, pref.shape)):
            if a != b:
                return jax.lax.dynamic_update_slice_in_dim(
                    full, pref.astype(full.dtype), 0, axis=ax)
        return pref
    return jax.tree.map(place, caches, prefill_caches)


def generate(cfg: ModelConfig, params, prompts, scfg: ServeConfig,
             frontier=None):
    """prompts: (B, S0) int32.  Returns (tokens (B, S0+new), stats)."""
    bm = MZ.build(cfg)
    b, s0 = prompts.shape
    batch = {"tokens": prompts}
    if frontier is not None:
        batch["frontier"] = frontier
    t0 = time.perf_counter()
    logits, pcaches = jax.jit(bm.prefill_step)(params, batch)
    caches = MZ.init_cache(cfg, b, scfg.cache_len)
    caches = _copy_prefill_into_cache(cfg, pcaches, caches, s0)
    prefill_s = time.perf_counter() - t0

    decode = jax.jit(bm.decode_step)
    key = jax.random.PRNGKey(scfg.seed)
    tokens = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    t0 = time.perf_counter()
    # vlm: the cache already contains n_patches prefix positions
    pos0 = s0 + (cfg.n_patches if cfg.family == "vlm" else 0)
    for i in range(scfg.max_new_tokens - 1):
        logits, caches = decode(params, caches, tokens[-1][:, None],
                                jnp.asarray(pos0 + i, jnp.int32))
        lg = logits[:, -1]
        if scfg.greedy:
            nxt = jnp.argmax(lg, axis=-1)
        else:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, lg / scfg.temperature)
        tokens.append(nxt.astype(jnp.int32))
    new = jnp.stack(tokens, axis=1)
    decode_s = time.perf_counter() - t0
    stats = {"prefill_s": prefill_s, "decode_s": decode_s,
             "tokens_per_s": b * (scfg.max_new_tokens - 1) /
             max(decode_s, 1e-9)}
    return jnp.concatenate([prompts, new], axis=1), stats
