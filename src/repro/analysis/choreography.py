"""The proc-engine protocol as data: roles, rounds, and frame budgets.

This module is commlint's ground truth.  Every wire interaction of the
multi-process runtime (launch/runtime/{worker,session,net}.py) is
declared here as a `Round`: which kind it rides on, which role sends and
which receives, the per-leg cardinality (one frame vs a peer loop), the
step/tag discipline, the measured_comm phase its sends must be counted
under, and the payload format.  commlint.py extracts the actual call
sites from the source and diffs them against this spec; the COM rules in
registry.RULES are the diff categories.

The same declaration doubles as the *static comm budget*:
`frames_by_phase(P, iters, history)` computes the exact number of frames
a clean run sends per measured_comm phase -- cross-checked against
`core/cost_model.proc_net_frames` (COM009) and, in
benchmarks/procnet_bench.py and tests/test_runtime_engine.py, against
the live `TrainResult.measured_comm["frames_by_phase"]` counters
bit-for-bit.  Stale frames dropped by `recv_any` are counted at the
*send* side like every other frame, so the budget is timing-invariant;
the receiver-side `measured_comm["dropped_frames"]` record is excluded
from this comparison by construction.

Grammar (documented in docs/ANALYSIS.md "Choreography grammar"):

  Leg(role, cardinality)      one side of a round.  role is "worker" or
                              "coord"; cardinality is "one" (a single
                              frame per occurrence), "per_peer" (a loop
                              over the other workers, P-1 frames) or
                              "per_worker" (a loop over all P workers).
  Round(name, kind, tag, scope, phase, payload, send, recv, ...)
      scope   "session" (once per run), "step" (once per training step),
              "history_step" (once per step on history runs only),
              "error" (failure path, zero frames in a clean run).
      phase   the measured_comm phase every send of the round must pass
              as its `phase=` kwarg (or inherit as the default).
      payload "array" (wire.share_payload / wire.pack_array), "pickle"
              (a registered control frame -- the ONLY sanctioned pickle
              sites), "json" (UTF-8 json.dumps), or "empty".
      adaptive  the recv leg is a straggler-tolerant collect: it must
              own at least one `recv_any` with an explicit bounded
              timeout (COM006).
      barrier both legs gate progress; a half-instantiated barrier
              round is a deadlock finding (COM005).
"""

from __future__ import annotations

import dataclasses

#: wire kind name -> header id, mirroring launch/runtime/net.py.  commlint
#: cross-checks the two tables (COM007 fires on drift) so the spec can
#: never silently fall behind the transport.
KINDS = {
    "HELLO": 1,
    "LISTEN": 2,
    "SESSION": 3,
    "READY": 4,
    "START": 5,
    "ENC": 6,
    "SHARE": 7,
    "OPEN": 8,
    "OPENED": 9,
    "RESULT": 10,
    "BYE": 11,
    "ERR": 12,
}

#: tag sub-channel names -> values (OPEN/OPENED carry these)
TAGS = {"TAG_TRUNC": 0, "TAG_HIST": 1}

ROLES = ("worker", "coord")

#: measured_comm phases a clean run populates, in protocol order
PHASES = ("setup", "encode", "exchange", "trunc_open", "open_model")


@dataclasses.dataclass(frozen=True)
class Leg:
    role: str            # "worker" | "coord"
    cardinality: str     # "one" | "per_peer" | "per_worker"


@dataclasses.dataclass(frozen=True)
class Round:
    name: str
    kind: str            # key into KINDS
    scope: str           # "session" | "step" | "history_step" | "error"
    phase: str           # measured_comm phase of the sends
    payload: str         # "array" | "pickle" | "json" | "empty"
    send: Leg
    recv: Leg | None     # None -> fire-and-forget (transport dispatches)
    tag: str | None = None      # key into TAGS; None -> tag 0, untagged
    adaptive: bool = False      # recv is a bounded-timeout collect
    barrier: bool = True        # both legs gate progress
    order: int = 0              # position in the per-role choreography
    extract: bool = False       # False: transport-internal (net.py only)

    def occurrences(self, iters: int, history: bool) -> int:
        if self.scope == "session":
            return 1
        if self.scope == "step":
            return iters
        if self.scope == "history_step":
            return iters if history else 0
        return 0                              # "error": clean-run budget

    def frames_per_occurrence(self, procs: int) -> int:
        """Frames the SEND leg emits per occurrence, across all P workers."""
        if self.kind == "HELLO":
            # every worker dials the coordinator (P) plus each lower-ranked
            # peer of the full mesh (sum over ranks = P*(P-1)/2); the
            # coordinator never dials.
            return procs + procs * (procs - 1) // 2
        per_role = {"worker": procs, "coord": 1}[self.send.role]
        per_leg = {"one": 1,
                   "per_peer": procs - 1,
                   "per_worker": procs}[self.send.cardinality]
        return per_role * per_leg


def _mk_rounds():
    w1 = Leg("worker", "one")
    wp = Leg("worker", "per_peer")
    cw = Leg("coord", "per_worker")
    rounds = [
        # transport handshake: emitted inside net.Node._connect, not a
        # node.send site -- budget-only (extract=False keeps the
        # extractor from demanding call sites for it).
        Round("hello", "HELLO", "session", "setup", "empty",
              Leg("worker", "one"), None, barrier=False),
        Round("listen", "LISTEN", "session", "setup", "pickle",
              w1, cw),
        Round("session_deal", "SESSION", "session", "setup", "pickle",
              cw, w1),
        Round("ready", "READY", "session", "setup", "empty", w1, cw),
        Round("start", "START", "session", "setup", "empty", cw, w1),
        Round("enc", "ENC", "step", "encode", "array",
              wp, Leg("worker", "per_peer")),
        Round("share", "SHARE", "step", "exchange", "array",
              wp, Leg("worker", "per_peer"), adaptive=True),
        Round("open_trunc", "OPEN", "step", "trunc_open", "array",
              w1, cw, tag="TAG_TRUNC"),
        Round("opened_trunc", "OPENED", "step", "trunc_open", "array",
              cw, w1, tag="TAG_TRUNC"),
        Round("open_hist", "OPEN", "history_step", "open_model", "array",
              w1, cw, tag="TAG_HIST"),
        Round("result", "RESULT", "session", "open_model", "pickle",
              w1, cw),
        Round("bye", "BYE", "session", "setup", "empty", cw, w1),
        # failure path: the receiving transport turns it into PeerFailure
        # inside net._dispatch, so there is no recv site to demand.
        Round("err", "ERR", "error", "setup", "json", w1, None,
              barrier=False),
    ]
    return tuple(
        dataclasses.replace(r, order=i, extract=r.kind != "HELLO")
        for i, r in enumerate(rounds))


ROUNDS = _mk_rounds()

#: the sanctioned pickle-over-the-wire control frames (COM008): anything
#: else serializing with pickle near the wire is a finding.
PICKLE_ROUNDS = tuple(r.name for r in ROUNDS if r.payload == "pickle")


def rounds_for(kind: str, tag: str | None = None):
    """Rounds riding on `kind`; a concrete tag narrows to its sub-channel."""
    hits = [r for r in ROUNDS if r.kind == kind]
    if tag is not None:
        exact = [r for r in hits if r.tag == tag]
        if exact:
            return exact
    return hits


def frames_by_phase(procs: int, iters: int, history: bool = False) -> dict:
    """Exact per-phase SENT frame counts of one clean proc:P run.

    Closed forms (P = procs, J = iters):
      setup      = P(P-1)/2 + 6P   (HELLO mesh+coord, LISTEN, SESSION,
                                    READY, START, BYE)
      encode     = P(P-1) * J      (ENC all-to-all)
      exchange   = P(P-1) * J      (SHARE all-to-all)
      trunc_open = 2P * J          (OPEN gather + OPENED broadcast)
      open_model = P*J [history] + P  (per-step model opening + RESULT)
    Zero-frame phases are omitted so the dict compares bit-for-bit with
    measured_comm["frames_by_phase"] at any P (P=1 sends no ENC/SHARE).
    """
    out: dict = {}
    for r in ROUNDS:
        n = r.frames_per_occurrence(procs) * r.occurrences(iters, history)
        if n:
            out[r.phase] = out.get(r.phase, 0) + n
    return out
