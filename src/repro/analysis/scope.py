"""Analysis scope: which files under the tree seclint actually checks.

The seed repo carries dormant LM-era modules (`models/`, most of
`configs/`) that predate the COPML protocol work and never touch shares
or field arrays.  They are excluded here explicitly -- out-of-protocol
legacy code, documented in docs/ANALYSIS.md -- so the gate's signal
stays about the MPC hot path.  Everything else under src/repro is in
scope; in particular the secure-serving package `serve/` (which holds
live model shares) is fully analyzed.
"""

from __future__ import annotations

import os

#: path fragments (relative to the `repro` package root) excluded from
#: analysis.  Directories end with "/".
EXCLUDED = (
    "models/",
)

#: configs/ is excluded except the protocol-era entries
CONFIGS_KEEP = ("__init__.py", "copml_logreg.py", "registry.py")


def _package_rel(path: str) -> str:
    """Path relative to the innermost `repro` package dir, '' if not inside."""
    norm = os.path.abspath(path).replace("\\", "/")
    marker = "/repro/"
    pos = norm.rfind(marker)
    if pos < 0:
        return ""
    return norm[pos + len(marker):]


def in_scope(path: str) -> bool:
    rel = _package_rel(path)
    if not rel:
        return True  # non-package files (fixtures, tmp copies): analyze
    for ex in EXCLUDED:
        if ex.endswith("/"):
            if rel.startswith(ex):
                return False
        elif rel == ex:
            return False
    if rel.startswith("configs/"):
        return os.path.basename(rel) in CONFIGS_KEEP
    return True
