"""Rule catalog and the sources / sinks / propagators registry.

Everything seclint believes about the world outside the file under
analysis lives here: which calls *create* secrets, which calls are
*sanctioned declassify sinks*, which calls merely move values around,
and which calls pull a value onto the host where a secret must never go.
The tables are keyed by fully-resolved dotted names (`repro.core.shamir
.share`, `numpy.asarray`); `<prefix>.*` entries act as longest-prefix
wildcards.
"""

from __future__ import annotations

# --------------------------------------------------------------------------
# taint labels
# --------------------------------------------------------------------------

SHARE = "share"      # Shamir share of a secret
CODED = "coded"      # LCC-coded slice
RAND = "rand"        # dealer / offline randomness
FIELD = "field"      # value lives in the field domain F_p
REDUCED = "reduced"  # known canonical in [0, p)

SECRET = frozenset({SHARE, CODED, RAND})

#: annotation name -> label set (annotations are the analyzer's ground truth)
ANNOT_LABELS = {
    "Share": frozenset({SHARE, FIELD, REDUCED}),
    "Coded": frozenset({CODED, FIELD, REDUCED}),
    "SecretRand": frozenset({RAND, FIELD, REDUCED}),
    "Public": frozenset({FIELD, REDUCED}),
    "Opened": frozenset(),  # sanctioned declassification: no residual taint
}

#: the COPML field modulus; any other modulus literal >= SMALL_MOD_FLOOR
#: appearing as the right side of `%` is a foreign-modulus finding.
P_VALUE = (1 << 26) - 5
SMALL_MOD_FLOOR = 1 << 13  # `% 2`, `% block` index math stays exempt

# --------------------------------------------------------------------------
# rule catalog
# --------------------------------------------------------------------------

RULES = {
    "SEC001": "secret-tainted value reaches a host escape "
              "(np.asarray / int() / .item() / print / logging)",
    "SEC002": "secret-dependent Python `if`/`while` "
              "(leak channel + jit-recompile hazard)",
    "SEC003": "secret-tainted value crosses into an unregistered "
              "external module without a sanctioned sink",
    "FLD001": "raw `+`/`-`/`*`/`@`/`%`/`**` on a field-domain array "
              "outside core/field.py / kernels/ wrappers",
    "FLD002": "narrowing dtype cast of a field value not dominated "
              "by a `% field.P` reduction",
    "FLD003": "float dtype touching a field-domain value",
    "FLD004": "modulus literal other than field.P",
    "WVR001": "malformed seclint waiver pragma",
    "WVR002": "unused seclint waiver pragma (strict mode only)",
    # --- commlint (the `comm` pass): choreography + comm-cost rules -------
    "COM001": "orphan send: a wire kind is sent but no matching recv "
              "site exists for the receiving role",
    "COM002": "unfulfillable recv: a wire kind is awaited but never "
              "sent by the declared sending role",
    "COM003": "cardinality/addressing mismatch: call site's peer-loop "
              "shape or peer role contradicts the round's declared legs",
    "COM004": "step/tag/phase discipline violation on a wire site or "
              "across a matched send/recv pair",
    "COM005": "choreography deadlock: missing barrier leg, "
              "uninstantiated round, or a recv-before-send cycle in "
              "the progress simulation",
    "COM006": "adaptive-collect violation: recv_any without a bounded "
              "timeout, or an adaptive round with no recv_any site",
    "COM007": "inventory failure: wire kind absent from the "
              "choreography spec, or spec/transport kind-table drift",
    "COM008": "pickle payload outside the registered control frames "
              "(LISTEN/SESSION/RESULT), or ad-hoc bytes on an array round",
    "COM009": "static frame budget divergence between the choreography "
              "spec and core/cost_model.proc_net_frames",
}

# --------------------------------------------------------------------------
# call effects
# --------------------------------------------------------------------------
# kind semantics (u = union of argument label sets):
#   source     -> labels | (u & SECRET)        creates a secret domain
#   open       -> (u - {share, rand}) | {field, reduced}   declassify sink
#   decode     -> (u - {coded}) | {field, reduced}         LCC decode sink
#   declassify -> {}                            fully sanctioned opening
#   fieldop    -> {field, reduced} | (u & SECRET)   exact mod-p wrapper
#   dequant    -> u - {field, reduced}          leaves the field domain
#   public     -> {field, reduced}              public field-domain constant
#   plain      -> {}                            no taint
#   propagate  -> u (dropping `reduced` if any field arg was unreduced)
#   escape     -> {} ; SEC001 if any argument is secret
#   replace    -> propagate + keep the dataclass type of arg 0

EFFECTS = {
    # --- field arithmetic: the wrappers ARE the sanctioned ops ------------
    "repro.core.field.*": {"kind": "fieldop"},
    # explicit reduction sites (also in REDUCE_SITES below): their result
    # is canonical in [0, p), so a following narrowing cast passes FLD002
    "repro.core.field.barrett_reduce": {"kind": "fieldop"},
    "repro.core.field.fold26": {"kind": "fieldop"},
    "repro.core.field.random_field": {
        "kind": "source", "labels": frozenset({RAND, FIELD, REDUCED})},
    "repro.core.field.host_inv": {"kind": "public"},
    "repro.core.field.host_lagrange_coeffs": {"kind": "public"},

    # --- Shamir sharing ----------------------------------------------------
    "repro.core.shamir.share": {
        "kind": "source", "labels": frozenset({SHARE, FIELD, REDUCED})},
    "repro.core.shamir.share_batch": {
        "kind": "source", "labels": frozenset({SHARE, FIELD, REDUCED})},
    "repro.core.shamir.reshare": {
        "kind": "source", "labels": frozenset({SHARE, FIELD, REDUCED})},
    "repro.core.shamir.reconstruct": {"kind": "open"},
    "repro.core.shamir.reconstruct_dyn": {"kind": "open"},
    "repro.core.shamir.recon_weights": {"kind": "public"},
    "repro.core.shamir.step_subset_arrays": {"kind": "public"},
    "repro.core.shamir.*": {"kind": "public"},

    # --- MPC primitives ----------------------------------------------------
    "repro.core.mpc.open_shares": {"kind": "open"},
    "repro.core.mpc.*": {"kind": "fieldop"},

    # --- LCC coding ---------------------------------------------------------
    "repro.core.lagrange.lcc_encode": {
        "kind": "source", "labels": frozenset({CODED, FIELD, REDUCED})},
    "repro.core.lagrange.lcc_decode": {"kind": "decode"},
    "repro.core.lagrange.encode_matrix": {"kind": "public"},
    "repro.core.lagrange.decode_matrix": {"kind": "public"},
    "repro.core.lagrange.*": {"kind": "propagate"},

    # --- quantization -------------------------------------------------------
    "repro.core.quantize.quantize": {"kind": "fieldop"},
    "repro.core.quantize.dequantize": {"kind": "dequant"},
    "repro.core.quantize.signed_value": {"kind": "dequant"},
    "repro.core.quantize.*": {"kind": "propagate"},

    # --- secure serving -----------------------------------------------------
    # open_logits is the serving path's ONLY sanctioned sink: it
    # reconstructs per-query logits (a (B, C') public output), never
    # anything model-shaped.  Everything else in serve/ stays in the
    # share domain and merely propagates taint.
    "repro.serve.coded.open_logits": {"kind": "open"},
    "repro.serve.coded.serving_points": {"kind": "public"},
    "repro.serve.coded.reference_scores": {"kind": "public"},
    "repro.serve.*": {"kind": "propagate"},

    # --- multi-process runtime ---------------------------------------------
    # share_payload is THE sanctioned cross-process sink: the runtime's
    # equivalent of `-> Opened` for sends.  Its output is an opaque wire
    # blob addressed to exactly one shareholder, so by the (t, N)-secrecy
    # argument it carries no residual taint; any OTHER serialization of a
    # share (`.tobytes()`, np.asarray, pickle) still flags SEC001/SEC003
    # (tests/fixtures/seclint/procsend_bad.py proves it).
    "repro.launch.runtime.wire.share_payload": {"kind": "declassify"},
    "repro.launch.runtime.wire.pack_array": {"kind": "propagate"},
    "repro.launch.runtime.*": {"kind": "propagate"},

    # --- everything else repro-internal ------------------------------------
    "repro.core.truncation.*": {"kind": "propagate"},
    "repro.core.meshutil.*": {"kind": "propagate"},
    "repro.core.labels.*": {"kind": "plain"},
    "repro.kernels.*": {"kind": "propagate"},
    "repro.*": {"kind": "propagate"},

    # --- dataclasses --------------------------------------------------------
    "dataclasses.replace": {"kind": "replace"},
    "dataclasses.*": {"kind": "propagate"},

    # --- host escapes -------------------------------------------------------
    "numpy.asarray": {"kind": "escape"},
    "numpy.array": {"kind": "escape"},
    "numpy.save": {"kind": "escape"},
    "numpy.savez": {"kind": "escape"},
    "numpy.savetxt": {"kind": "escape"},
    "numpy.testing.*": {"kind": "escape"},
    "numpy.*": {"kind": "propagate"},
    "jax.debug.*": {"kind": "escape"},
    "jax.*": {"kind": "propagate"},
    "logging.*": {"kind": "escape"},
    "warnings.*": {"kind": "escape"},
    "builtins.print": {"kind": "escape"},
    "builtins.int": {"kind": "escape"},
    "builtins.float": {"kind": "escape"},
    "builtins.bool": {"kind": "escape"},

    # --- misc stdlib that shows up in the hot path --------------------------
    "functools.*": {"kind": "propagate"},
    "itertools.*": {"kind": "propagate"},
    "math.*": {"kind": "plain"},
    "copy.*": {"kind": "propagate"},
    "operator.*": {"kind": "propagate"},
}

#: module roots that never count as a SEC003 boundary (registered above or
#: known-inert).  Anything else receiving a secret argument is a finding.
SAFE_ROOTS = frozenset({
    "repro", "jax", "jaxlib", "numpy", "builtins",
    "dataclasses", "functools", "itertools", "math", "copy", "operator",
    "typing", "collections", "abc", "enum", "contextlib",
    "os", "sys", "time", "argparse", "pathlib", "re", "string",
})

#: dotted prefixes that are known *modules* (not attributes), derived from
#: the EFFECTS keys.  Lets `from repro.core import field` resolve even when
#: repro itself is not part of the indexed tree (fixtures, tmp copies).
KNOWN_MODULES = frozenset(
    key.rsplit(".", 1)[0] for key in EFFECTS if not key.endswith("*")
) | frozenset(
    key[:-2] for key in EFFECTS if key.endswith(".*")
) | frozenset({
    "jax.numpy", "jax.random", "jax.lax", "jax.debug", "numpy.testing",
    "repro.core", "repro.kernels", "repro.api", "repro.core.protocol",
    "repro.core.secure_agg", "repro.core.baselines", "repro.core.objectives",
    "repro.launch", "repro.launch.runtime",
})

# --------------------------------------------------------------------------
# array-method semantics (receiver of unknown type)
# --------------------------------------------------------------------------

#: methods that materialize on the host -> SEC001 when the receiver is secret
ESCAPE_METHODS = frozenset({"item", "tolist", "tobytes"})

#: arithmetic reductions: stay in the field but lose canonicity
ARITH_METHODS = frozenset({
    "sum", "prod", "dot", "matmul", "cumsum", "cumprod",
    "mean", "var", "std", "trace",
})

#: attribute reads that are metadata, never data
META_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "nbytes",
                        "itemsize", "sharding"})

#: method calls whose result depends only on shapes/dtypes, never on the
#: argument values: jax AOT compilation and its analysis surfaces.  The
#: result of `jit(f).lower(shares)` is a program, not the shares.
META_METHODS = frozenset({"lower", "compile", "memory_analysis",
                          "cost_analysis", "as_text", "as_hlo_text"})

#: astype targets
NARROW_DTYPES = frozenset({"int32", "uint32", "int16", "uint16",
                           "int8", "uint8", "bool_"})
FLOAT_DTYPES = frozenset({"float16", "float32", "float64", "float_",
                          "double", "bfloat16", "complex64", "complex128"})

# --------------------------------------------------------------------------
# FLD exemptions: these modules ARE the arithmetic layer (limb packing,
# bit-level folds) -- the FLD001/FLD002/FLD003 patterns are their job.
# FLD004 (foreign modulus) still applies everywhere.
# --------------------------------------------------------------------------

FLD_EXEMPT_SUFFIXES = ("core/field.py", "core/quantize.py")
FLD_EXEMPT_DIRS = ("kernels/",)


#: calls that ARE a full mod-p reduction.  Like the `% field.P` idiom,
#: passing an expression to one of these sanctions the raw `+`/`-`/`*`
#: arithmetic in its argument subtree (FLD001): the mu-multiply/shift and
#: q*p subtract inside barrett_reduce, or a lazy limb accumulation handed
#: to fold26, are the reduction itself, not an unreduced leak.  The
#: int32 magnitude bound is on the author, exactly as with `% field.P`.
REDUCE_SITES = frozenset({
    "repro.core.field.barrett_reduce",
    "repro.core.field.fold26",
})


def fld_exempt(relpath: str) -> bool:
    rel = relpath.replace("\\", "/")
    if rel.endswith(FLD_EXEMPT_SUFFIXES):
        return True
    return any(("/" + d) in rel or rel.startswith(d)
               for d in FLD_EXEMPT_DIRS)


def lookup_effect(dotted: str):
    """Longest-prefix effect lookup; None when the name is unregistered."""
    if dotted in EFFECTS:
        return EFFECTS[dotted]
    parts = dotted.split(".")
    for cut in range(len(parts) - 1, 0, -1):
        key = ".".join(parts[:cut]) + ".*"
        if key in EFFECTS:
            return EFFECTS[key]
    return None
