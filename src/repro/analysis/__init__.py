"""Static analyzers for the COPML hot path: seclint + commlint.

Two pass families share one engine, waiver grammar, report format, and
CLI (`python -m repro.analysis src/repro`, or `scripts/seclint.py`):

  * **sec** (seclint, SEC/FLD/WVR rules): secrecy-taint + field
    arithmetic analysis of the MPC compute path.
  * **comm** (commlint, COM rules): choreography + comm-cost analysis of
    the multi-process protocol -- call sites of the proc-engine runtime
    diffed against the declarative round spec in `choreography.py`, plus
    the static frame budget cross-checked against `core/cost_model.py`.

`--pass {sec,comm,all}` selects a family; `--changed-only` restricts to
git-dirty files; `--cache PATH` memoizes per-file sec findings.  See
docs/ANALYSIS.md for the rule catalog, the taint model, the choreography
grammar, and the waiver-pragma grammar.

Public API:
    analyze_paths(paths, ...) -> AnalysisResult (.findings / .active /
                                 .waived / .unused_waivers)
    RULES                     -- {rule_id: one-line description}
"""

from __future__ import annotations

from .engine import analyze_paths
from .registry import RULES
from .report import Finding, render_budget, render_json, render_text

__all__ = [
    "analyze_paths",
    "Finding",
    "RULES",
    "render_text",
    "render_json",
    "render_budget",
]
