"""seclint: secrecy-taint + field-arithmetic static analyzer for the MPC hot path.

Run it as `python -m repro.analysis src/repro` (or `scripts/seclint.py`).
See docs/ANALYSIS.md for the rule catalog, the taint model, and the
waiver-pragma grammar.

Public API:
    analyze_paths(paths, ...) -> AnalysisResult (.findings / .active /
                                 .waived / .unused_waivers)
    RULES                     -- {rule_id: one-line description}
"""

from __future__ import annotations

from .engine import analyze_paths
from .registry import RULES
from .report import Finding, render_budget, render_json, render_text

__all__ = [
    "analyze_paths",
    "Finding",
    "RULES",
    "render_text",
    "render_json",
    "render_budget",
]
