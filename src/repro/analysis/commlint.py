"""commlint: choreography + comm-cost checks for the proc-engine protocol.

The pass runs inside `analyze_paths` (``--pass comm``; the default runs
seclint and commlint together) and shares seclint's waiver / report /
CLI infrastructure: every check lands as a `Finding` whose COM rule id
lives in registry.RULES, so the pragma grammar, the budget report, and
`scripts/check_docs.py` cover both pass families for free.

How it works:

1.  Runtime *groups* are discovered structurally: any directory in the
    indexed tree holding both a ``worker.py`` and a ``session.py`` is a
    runtime (the real one is ``launch/runtime/``; the fixture corpus
    under tests/fixtures/commlint/ provides miniature ones).  A
    ``net.py`` sibling marks the group as a full transport: its kind
    table is cross-checked against the spec and the group must
    instantiate every declared round.
2.  An AST extractor inventories every ``node.send`` / ``node.recv`` /
    ``node.recv_any`` call site -- kind, peer expression, step/tag
    expressions, timeout policy, payload serialization, and
    enclosing-loop cardinality (ast.For / ast.While / comprehension
    generators all count; a peer expression that is an enclosing loop
    target makes the site a peer-loop site).
3.  Sites are matched to the declarative rounds in choreography.py and
    diffed: COM001/002 orphan/unfulfillable legs, COM003 cardinality +
    addressing, COM004 step/tag/phase discipline, COM005 deadlock
    (missing barrier legs plus a progress simulation over the per-role
    event order), COM006 adaptive-collect timeouts, COM007 inventory
    failures (unknown kinds, spec/transport drift), COM008 pickle
    discipline (bridging to seclint's `share_payload` declassify sink),
    COM009 static frame budget vs `core/cost_model.proc_net_frames`.

The analysis is purely syntactic -- nothing from the target tree is
imported -- so it runs identically on the live runtime, on tempdir
corruption-drill copies, and on the fixture corpus.
"""

from __future__ import annotations

import ast
import dataclasses
import os

from . import choreography as spec
from .report import Finding

_GROUP_FILES = ("worker.py", "session.py", "net.py")
_ROLE_OF = {"worker.py": "worker", "session.py": "coord"}

#: wire kinds allowed to carry pickle (the registered control frames)
_PICKLE_KINDS = frozenset(
    r.kind for r in spec.ROUNDS if r.payload == "pickle")

#: (procs, iters, history) samples the COM009 budget cross-check runs on
_BUDGET_SAMPLES = ((1, 1, False), (3, 5, False), (4, 10, True),
                   (8, 2, True))


@dataclasses.dataclass
class Site:
    """One inventoried wire call site."""
    path: str
    line: int
    col: int
    func: str
    role: str            # "worker" | "coord"
    op: str              # "send" | "recv" | "recv_any"
    kind: str | None     # resolved kind name, None when unresolvable
    kind_raw: str        # source text of the kind expression
    peer: str            # "coord" | "loop" | "const" | "var" | "any"
    multi: bool          # emitted/consumed inside a peer loop
    step: tuple          # ("none" | "const" | "var", value)
    tag: tuple           # ("none" | "attr" | "const" | "var", value)
    phase: tuple         # ("none" | "const" | "var", value)   (sends)
    timeout: bool        # explicit timeout argument present
    payload: str         # pickle|json|array|raw|empty|unknown


def _find(rule, message, site_or_path, line=0):
    if isinstance(site_or_path, Site):
        return Finding(rule, message, site_or_path.path, site_or_path.line,
                       site_or_path.col)
    return Finding(rule, message, site_or_path, line)


# --------------------------------------------------------------------------
# extraction
# --------------------------------------------------------------------------

def _expr_class(expr):
    if expr is None:
        return ("none", None)
    if isinstance(expr, ast.Constant):
        return ("const", expr.value)
    return ("var", ast.unparse(expr))


def _tag_class(expr):
    if expr is None:
        return ("none", None)
    if isinstance(expr, ast.Constant):
        return ("none", None) if expr.value == 0 else ("const", expr.value)
    if isinstance(expr, ast.Attribute):
        return ("attr", expr.attr)
    if isinstance(expr, ast.Name):
        if expr.id in spec.TAGS or expr.id.startswith("TAG_"):
            return ("attr", expr.id)
        return ("var", expr.id)
    return ("var", ast.unparse(expr))


def _kind_name(expr):
    """(resolved kind name or None, raw source text)."""
    if expr is None:
        return None, "<missing>"
    raw = ast.unparse(expr)
    if isinstance(expr, ast.Attribute):
        return expr.attr, raw
    if isinstance(expr, ast.Name):
        return expr.id, raw
    if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
        rev = {v: k for k, v in spec.KINDS.items()}
        return rev.get(expr.value), raw
    return None, raw


class _Extractor(ast.NodeVisitor):
    """Walk one worker.py / session.py module and inventory wire sites."""

    def __init__(self, path, role):
        self.path = path
        self.role = role
        self.sites: list = []
        self.site_by_node: dict = {}       # id(call) -> Site
        self.pickle_loads: list = []       # (call node, func)
        self.pickle_dumps: list = []       # (call node, func)
        self.covered_dumps: set = set()    # dump ids inside send payloads
        self.covered_names: set = set()    # (func, name) used as a payload
        self.pending_dumps: dict = {}      # (func, name) -> {dump ids}
        self.bindings: dict = {}           # (func, name) -> recv Site
        self.payload_bindings: dict = {}   # (func, name) -> payload class
        self._funcs = ["<module>"]
        self._loops: list = []             # per-level sets of target names

    # -- context ----------------------------------------------------------

    @property
    def func(self):
        return self._funcs[-1]

    def visit_FunctionDef(self, node):
        self._funcs.append(f"{self.func}.{node.name}")
        self.generic_visit(node)
        self._funcs.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    @staticmethod
    def _target_names(tgt):
        return {n.id for n in ast.walk(tgt) if isinstance(n, ast.Name)}

    def visit_For(self, node):
        self.visit(node.iter)
        self._loops.append(self._target_names(node.target))
        for sub in node.body + node.orelse:
            self.visit(sub)
        self._loops.pop()

    visit_AsyncFor = visit_For

    def visit_While(self, node):
        self.visit(node.test)
        self._loops.append(set())
        for sub in node.body + node.orelse:
            self.visit(sub)
        self._loops.pop()

    def _comprehension(self, node, inner):
        pushed = 0
        for gen in node.generators:
            self.visit(gen.iter)
            self._loops.append(self._target_names(gen.target))
            pushed += 1
            for cond in gen.ifs:
                self.visit(cond)
        for expr in inner:
            self.visit(expr)
        del self._loops[-pushed:]

    def visit_ListComp(self, node):
        self._comprehension(node, [node.elt])

    visit_SetComp = visit_GeneratorExp = visit_ListComp

    def visit_DictComp(self, node):
        self._comprehension(node, [node.key, node.value])

    def visit_Assign(self, node):
        self.visit(node.value)
        for tgt in node.targets:
            self.visit(tgt)
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            key = (self.func, node.targets[0].id)
            site = self.site_by_node.get(id(node.value))
            if site is not None and site.op in ("recv", "recv_any"):
                self.bindings[key] = site
            cls = self._payload_class(node.value, follow=False)
            if cls != "unknown":
                self.payload_bindings[key] = cls
                if cls == "pickle":
                    self.pending_dumps[key] = {
                        id(sub) for sub in ast.walk(node.value)
                        if self._is_pickle_dumps(sub)}

    # -- call sites -------------------------------------------------------

    @staticmethod
    def _is_pickle_dumps(node):
        return (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "dumps"
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "pickle")

    def visit_Call(self, node):
        f = node.func
        if isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            if f.attr in ("send", "recv", "recv_any"):
                self._site(node)
            elif f.value.id == "pickle" and f.attr in ("dumps", "loads"):
                bucket = (self.pickle_dumps if f.attr == "dumps"
                          else self.pickle_loads)
                bucket.append((node, self.func))
        self.generic_visit(node)

    def _site(self, call):
        op = call.func.attr
        args = call.args
        kws = {k.arg: k.value for k in call.keywords if k.arg}

        def arg(i, name):
            if name in kws:
                return kws[name]
            return args[i] if len(args) > i else None

        payload_e = phase_e = timeout_e = None
        if op == "send":
            kind_e, peer_e = arg(1, "kind"), arg(0, "dst")
            step_e, tag_e = arg(2, "step"), arg(3, "tag")
            payload_e, phase_e = arg(4, "payload"), kws.get("phase")
        elif op == "recv":
            kind_e, peer_e = arg(0, "kind"), arg(1, "src")
            step_e, tag_e = arg(2, "step"), arg(3, "tag")
            timeout_e = arg(4, "timeout")
        else:                                           # recv_any
            kind_e, peer_e, tag_e = arg(0, "kind"), None, None
            step_e, timeout_e = arg(1, "step"), arg(2, "timeout")

        kind, kind_raw = _kind_name(kind_e)
        peer, peer_name = self._peer(peer_e)
        in_loop = bool(self._loops)
        multi = (peer == "loop"
                 or (op == "recv_any" and in_loop)
                 or (peer == "any" and in_loop))
        site = Site(
            path=self.path, line=call.lineno, col=call.col_offset,
            func=self.func, role=self.role, op=op,
            kind=kind, kind_raw=kind_raw, peer=peer, multi=multi,
            step=_expr_class(step_e), tag=_tag_class(tag_e),
            phase=_expr_class(phase_e), timeout=timeout_e is not None,
            payload=self._payload_class(payload_e) if op == "send"
            else "unknown")
        self.sites.append(site)
        self.site_by_node[id(call)] = site
        if payload_e is not None:
            for sub in ast.walk(payload_e):
                if self._is_pickle_dumps(sub):
                    self.covered_dumps.add(id(sub))
            if isinstance(payload_e, ast.Name):
                self.covered_names.add((self.func, payload_e.id))

    def _peer(self, expr):
        if expr is None:
            return "any", None
        if isinstance(expr, ast.Attribute) and expr.attr == "COORD":
            return "coord", None
        if isinstance(expr, ast.Name):
            if expr.id == "COORD":
                return "coord", None
            if any(expr.id in targets for targets in self._loops):
                return "loop", expr.id
            return "var", expr.id
        if isinstance(expr, ast.Constant) and isinstance(expr.value, int):
            return ("coord", None) if expr.value == 0xFFFF \
                else ("const", expr.value)
        return "var", ast.unparse(expr)

    def _payload_class(self, expr, follow=True):
        if expr is None:
            return "empty"
        if isinstance(expr, ast.Constant):
            return "empty" if expr.value in (b"", "") else "raw"
        if isinstance(expr, ast.Name) and follow:
            return self.payload_bindings.get((self.func, expr.id), "unknown")
        found = set()
        for sub in ast.walk(expr):
            if not isinstance(sub, ast.Call):
                continue
            sf = sub.func
            if isinstance(sf, ast.Attribute):
                base = sf.value.id if isinstance(sf.value, ast.Name) else ""
                if sf.attr == "dumps" and base == "pickle":
                    found.add("pickle")
                elif sf.attr == "dumps" and base == "json":
                    found.add("json")
                elif sf.attr in ("share_payload", "pack_array"):
                    found.add("array")
                elif sf.attr in ("tobytes", "encode"):
                    found.add("raw")
            elif isinstance(sf, ast.Name):
                if sf.id in ("share_payload", "pack_array"):
                    found.add("array")
                elif sf.id == "bytes":
                    found.add("raw")
        for cls in ("pickle", "json", "array", "raw"):
            if cls in found:
                return cls
        return "unknown"

    # -- post-pass: pickle discipline (COM008) ----------------------------

    def pickle_findings(self):
        out = []
        for key in self.covered_names:
            self.covered_dumps |= self.pending_dumps.get(key, set())
        for node, _func in self.pickle_dumps:
            if id(node) not in self.covered_dumps:
                out.append(Finding(
                    "COM008", "pickle.dumps outside a registered wire "
                    "control frame (arrays cross processes only through "
                    "wire.share_payload, the seclint declassify sink)",
                    self.path, node.lineno, node.col_offset))
        for node, func in self.pickle_loads:
            site = None
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call) \
                        and id(sub) in self.site_by_node:
                    site = self.site_by_node[id(sub)]
                    break
                if site is None and isinstance(sub, ast.Attribute) \
                        and sub.attr == "payload" \
                        and isinstance(sub.value, ast.Name):
                    site = self.bindings.get((func, sub.value.id))
            if site is None:
                out.append(Finding(
                    "COM008", "pickle.loads of an unidentified payload "
                    "(cannot be tied to a registered control frame recv)",
                    self.path, node.lineno, node.col_offset))
            elif site.kind not in _PICKLE_KINDS:
                out.append(Finding(
                    "COM008", f"pickle.loads of a `{site.kind}` payload -- "
                    f"the registered pickle control frames are "
                    f"{sorted(_PICKLE_KINDS)}",
                    self.path, node.lineno, node.col_offset))
        return out


# --------------------------------------------------------------------------
# checks
# --------------------------------------------------------------------------

def _assign_sites(sites, findings):
    """Match sites to spec rounds; COM007 for inventory failures."""
    assigned = {r.name: {"send": [], "recv": []} for r in spec.ROUNDS}
    for s in sites:
        if s.kind is None or s.kind not in spec.KINDS:
            findings.append(_find(
                "COM007", f"wire kind `{s.kind_raw}` is absent from the "
                "choreography spec (inventory failure)", s))
            continue
        tag_name = s.tag[1] if s.tag[0] == "attr" else None
        if tag_name is not None and tag_name not in spec.TAGS:
            findings.append(_find(
                "COM004", f"unknown tag sub-channel `{tag_name}` on "
                f"`{s.kind}` (declared tags: {sorted(spec.TAGS)})", s))
            tag_name = None
        leg = "send" if s.op == "send" else "recv"
        cands = [r for r in spec.rounds_for(s.kind, tag_name)
                 if (r.send.role == s.role if leg == "send"
                     else r.recv is not None and r.recv.role == s.role)]
        if not cands:
            findings.append(_find(
                "COM007", f"no declared round matches this {s.role} "
                f"{s.op} of `{s.kind}` (inventory failure: wrong "
                "role/direction for every spec entry of that kind)", s))
            continue
        for r in cands:
            assigned[r.name][leg].append(s)
    return assigned


def _leg_checks(r, leg, leg_spec, peers_role, sites, findings):
    need_multi = leg_spec.cardinality in ("per_peer", "per_worker")
    for s in sites:
        if s.op != "recv_any" and s.multi != need_multi:
            how = ("a single-shot site" if not s.multi
                   else "inside a peer loop")
            findings.append(_find(
                "COM003", f"{leg} of `{r.kind}` is {how} but round "
                f"`{r.name}` declares cardinality "
                f"`{leg_spec.cardinality}`", s))
        if s.peer == "coord" and peers_role != "coord":
            findings.append(_find(
                "COM003", f"{leg} of `{r.kind}` addresses the "
                f"coordinator but round `{r.name}`'s peer role is "
                f"`{peers_role}`", s))
        if r.scope in ("step", "history_step"):
            if s.step[0] != "var":
                pin = "omits the step" if s.step[0] == "none" else \
                    f"pins step={s.step[1]!r}"
                findings.append(_find(
                    "COM004", f"round `{r.name}` is per-step but this "
                    f"{leg} site {pin} (step/tag discipline)", s))
        elif s.step[0] == "var" or (s.step[0] == "const" and s.step[1] != 0):
            findings.append(_find(
                "COM004", f"session-scoped round `{r.name}` must not "
                f"carry a step expression (got {s.step[1]!r})", s))
        if s.tag[0] == "attr" and s.tag[1] in spec.TAGS \
                and r.tag != s.tag[1]:
            findings.append(_find(
                "COM004", f"tag `{s.tag[1]}` does not match round "
                f"`{r.name}`'s sub-channel ({r.tag or 'untagged'})", s))
        if leg == "send":
            phase = (s.phase[1] if s.phase[0] == "const"
                     else "setup" if s.phase[0] == "none" else None)
            if phase is not None and phase != r.phase:
                findings.append(_find(
                    "COM004", f"send counted under measured_comm phase "
                    f"{phase!r} but round `{r.name}` is budgeted under "
                    f"{r.phase!r} (comm accounting would drift)", s))
            if s.payload == "pickle" and r.payload != "pickle":
                findings.append(_find(
                    "COM008", f"pickle payload on round `{r.name}` -- "
                    f"only {sorted(spec.PICKLE_ROUNDS)} are registered "
                    "pickle control frames", s))
            elif r.payload == "array" and s.payload in ("json", "raw"):
                findings.append(_find(
                    "COM008", f"round `{r.name}` carries field arrays; "
                    "serialize via wire.share_payload / wire.pack_array, "
                    "not ad-hoc bytes", s))
        if s.op == "recv_any" and not s.timeout:
            findings.append(_find(
                "COM006", "recv_any without an explicit bounded timeout "
                "(an adaptive collect must not block forever)", s))


def _round_checks(assigned, has_net, net_info, findings):
    for r in spec.ROUNDS:
        if not r.extract:
            continue
        sends, recvs = assigned[r.name]["send"], assigned[r.name]["recv"]
        if not sends and not recvs:
            if has_net and r.scope != "error":
                path, line = net_info["anchor"](r.kind)
                findings.append(Finding(
                    "COM005", f"round `{r.name}` ({r.kind}) is declared "
                    "in the choreography spec but never instantiated in "
                    "this runtime", path, line))
            continue
        if r.recv is not None:
            if sends and not recvs:
                findings.append(_find(
                    "COM001", f"`{r.kind}` sent by {r.send.role} but no "
                    f"matching {r.recv.role} recv site (orphan send, "
                    f"round `{r.name}`)", sends[0]))
                if r.barrier:
                    findings.append(_find(
                        "COM005", f"barrier round `{r.name}` is missing "
                        f"its recv leg: the {r.recv.role} side never "
                        "consumes the frame and the choreography stalls",
                        sends[0]))
            elif recvs and not sends:
                findings.append(_find(
                    "COM002", f"`{r.kind}` awaited by {r.recv.role} but "
                    f"never sent by {r.send.role} (unfulfillable recv, "
                    f"round `{r.name}`)", recvs[0]))
                if r.barrier:
                    findings.append(_find(
                        "COM005", f"barrier round `{r.name}` is missing "
                        "its send leg: every receiver blocks forever",
                        recvs[0]))
            if r.adaptive and recvs and not any(
                    s.op == "recv_any" and s.timeout for s in recvs):
                findings.append(_find(
                    "COM006", f"adaptive round `{r.name}`'s collect has "
                    "no bounded recv_any site -- a straggler stalls the "
                    "step instead of degrading the decode subset",
                    recvs[0]))
            # matched-pair step discipline
            if sends and recvs:
                def norm(s):
                    return ("const", 0) if s.step[0] == "none" else (
                        s.step[0], s.step[1] if s.step[0] == "const"
                        else None)
                classes = {norm(s) for s in sends + recvs}
                if len(classes) > 1:
                    odd = min(sends + recvs,
                              key=lambda s: (s.step[0] == "var", s.line))
                    findings.append(_find(
                        "COM004", f"matched send/recv pair of round "
                        f"`{r.name}` disagree on the step expression "
                        f"({sorted(classes)})", odd))
        _leg_checks(r, "send", r.send,
                    r.recv.role if r.recv is not None else "coord",
                    sends, findings)
        if r.recv is not None:
            _leg_checks(r, "recv", r.recv, r.send.role, recvs, findings)


def _simulate(assigned, findings):
    """Progress simulation over the per-role event order (COM005).

    Event order: two events of one role are ordered by line number when
    they share an innermost function, by spec round order otherwise
    (all workers run the same program, so a worker recv is fulfillable
    exactly when the symmetric worker send has completed)."""
    events = []
    for r in spec.ROUNDS:
        if not r.extract or r.scope == "error":
            continue
        for leg in ("send", "recv"):
            for s in assigned[r.name][leg]:
                events.append({"role": s.role, "func": s.func,
                               "line": s.line, "order": r.order,
                               "leg": leg, "round": r.name, "site": s})

    def before(a, b):
        if a is b or a["role"] != b["role"]:
            return False
        if a["func"] == b["func"] and a["line"] != b["line"]:
            return a["line"] < b["line"]
        return a["order"] < b["order"]

    done: set = set()
    changed = True
    while changed:
        changed = False
        for i, e in enumerate(events):
            if i in done:
                continue
            if any(j not in done for j, e2 in enumerate(events)
                   if e2["leg"] == "recv" and before(e2, e)):
                continue
            if e["leg"] == "recv" and not any(
                    j in done for j, e2 in enumerate(events)
                    if e2["round"] == e["round"] and e2["leg"] == "send"):
                continue
            done.add(i)
            changed = True
    stuck = [e for i, e in enumerate(events) if i not in done]
    if stuck:
        first = min(stuck, key=lambda e: (e["site"].path, e["line"]))
        chain = sorted({f"{e['role']}:{e['round']}.{e['leg']}"
                        for e in stuck})
        findings.append(_find(
            "COM005", "choreography deadlock: progress simulation leaves "
            f"{len(stuck)} event(s) permanently blocked "
            f"({', '.join(chain[:6])}{', ...' if len(chain) > 6 else ''})",
            first["site"]))


def _net_table(mi, findings):
    """Cross-check net.py's kind table against the spec (COM007)."""
    assigns: dict = {}
    kind_names: set = set()
    for node in mi.tree.body:
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1
                and isinstance(node.targets[0], ast.Name)):
            continue
        name = node.targets[0].id
        if isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int) and name.isupper():
            assigns[name] = (node.value.value, node.lineno)
        if name == "KIND_NAMES" and isinstance(node.value, ast.Dict):
            kind_names |= {k.id for k in node.value.keys
                           if isinstance(k, ast.Name)}
    if not kind_names:
        kind_names = {n for n in assigns if n != "COORD"
                      and not n.startswith("TAG_")}
    for name in sorted(kind_names - set(spec.KINDS)):
        _, line = assigns.get(name, (None, 1))
        findings.append(Finding(
            "COM007", f"transport kind `{name}` has no choreography spec "
            "entry (inventory failure)", mi.path, line))
    for name in sorted(set(spec.KINDS) - kind_names):
        findings.append(Finding(
            "COM007", f"spec kind `{name}` is missing from the transport "
            "kind table", mi.path, 1))
    for name, (val, line) in sorted(assigns.items()):
        if name in spec.KINDS and val != spec.KINDS[name]:
            findings.append(Finding(
                "COM007", f"kind id drift: transport has {name}={val} "
                f"but the spec declares {spec.KINDS[name]}",
                mi.path, line))

    def anchor(kind):
        _, line = assigns.get(kind, (None, 1))
        return mi.path, line

    return {"anchor": anchor}


def _budget_check(findings):
    """COM009: choreography budget vs cost_model.proc_net_frames."""
    try:
        from ..core import cost_model
        fn = cost_model.proc_net_frames
        cm_path = cost_model.__file__
    except Exception as exc:  # noqa: BLE001 -- unavailability IS a finding
        findings.append(Finding(
            "COM009", "cost_model.proc_net_frames unavailable for the "
            f"static frame-budget cross-check: {exc!r}",
            "src/repro/core/cost_model.py", 1))
        return
    for procs, iters, history in _BUDGET_SAMPLES:
        want = spec.frames_by_phase(procs, iters, history)
        try:
            got = {k: v for k, v in
                   fn(procs, iters, history).items() if v}
        except Exception as exc:  # noqa: BLE001
            findings.append(Finding(
                "COM009", f"proc_net_frames({procs}, {iters}, "
                f"history={history}) raised {exc!r}", cm_path, 1))
            continue
        if got != want:
            findings.append(Finding(
                "COM009", f"static frame budget diverges: "
                f"proc_net_frames({procs}, {iters}, history={history}) "
                f"= {got} but the choreography derives {want}",
                cm_path, 1))


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def _groups(index):
    """{dirpath: {basename: ModuleInfo}} for worker/session/net triples."""
    groups: dict = {}
    for mi in index.modules.values():
        base = os.path.basename(mi.path)
        if base in _GROUP_FILES:
            key = os.path.dirname(os.path.abspath(mi.path))
            groups.setdefault(key, {})[base] = mi
    return {d: g for d, g in groups.items()
            if "worker.py" in g and "session.py" in g}


def check_group(group) -> list:
    """Run every COM check on one runtime group; returns Findings."""
    findings: list = []
    sites: list = []
    for base, role in _ROLE_OF.items():
        ex = _Extractor(group[base].path, role)
        ex.visit(group[base].tree)
        findings.extend(ex.pickle_findings())
        sites.extend(ex.sites)
    assigned = _assign_sites(sites, findings)
    has_net = "net.py" in group
    net_info = {"anchor": lambda kind: (group["worker.py"].path, 1)}
    if has_net:
        net_info = _net_table(group["net.py"], findings)
    _round_checks(assigned, has_net, net_info, findings)
    _simulate(assigned, findings)
    if has_net:
        _budget_check(findings)
    return findings


def collect(index, run_paths) -> list:
    """The comm pass: check every runtime group touching `run_paths`.

    `index` is the engine's ProjectIndex (groups are discovered over ALL
    indexed modules so a --changed-only run of worker.py still sees its
    session.py counterpart); findings are only emitted for groups with
    at least one member in the analyzed set."""
    run = {os.path.abspath(p) for p in run_paths}
    findings: list = []
    for d in sorted(_groups(index)):
        group = _groups(index)[d]
        if any(os.path.abspath(mi.path) in run for mi in group.values()):
            findings.extend(check_group(group))
    return findings
