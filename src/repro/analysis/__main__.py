"""CLI: `python -m repro.analysis [paths...]`.

Runs both pass families by default: `sec` (seclint secrecy-taint +
field-arithmetic rules) and `comm` (commlint choreography + comm-cost
rules); `--pass` narrows to one.  Exit status 0 = clean (every finding
waived with a reason); 1 = unwaived findings (or, under --strict, ANY
findings/waivers).  Also reachable as `scripts/seclint.py`.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

from .cache import FindingsCache
from .engine import analyze_paths
from .registry import RULES
from .report import render_budget, render_json, render_text

_PASSES = {"sec": ("sec",), "comm": ("comm",), "all": ("sec", "comm")}


def _changed_files():
    """Absolute paths of .py files changed vs HEAD (plus untracked).

    Returns None when git is unavailable -- the caller falls back to a
    full run, which is always sound."""
    changed = set()
    for cmd in (["git", "diff", "--name-only", "HEAD"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            out = subprocess.run(cmd, capture_output=True, text=True,
                                 timeout=30, check=True).stdout
        except (OSError, subprocess.SubprocessError):
            return None
        changed |= {os.path.abspath(line) for line in out.splitlines()
                    if line.endswith(".py")}
    return changed


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis",
        description="static analyzers for the COPML hot path: seclint "
                    "(secrecy taint + field arithmetic) and commlint "
                    "(protocol choreography + comm cost)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or trees to analyze (default: src/repro)")
    ap.add_argument("--pass", dest="passes", choices=sorted(_PASSES),
                    default="all",
                    help="which rule family to run (default: all)")
    ap.add_argument("--package", default="",
                    help="dotted package context for explicitly-listed "
                         "files (resolves their relative imports), e.g. "
                         "--package repro.core")
    ap.add_argument("--strict", action="store_true",
                    help="treat every waiver (used or unused) as an error")
    ap.add_argument("--changed-only", action="store_true",
                    help="only analyze files changed vs git HEAD "
                         "(everything is still indexed; commlint still "
                         "sees whole worker/session groups)")
    ap.add_argument("--cache", metavar="PATH", default="",
                    help="memoize per-file sec findings in a JSON cache "
                         "keyed on file/dep mtimes")
    ap.add_argument("--json", metavar="PATH", default="",
                    help="write the full findings report as JSON")
    ap.add_argument("--budget-report", metavar="PATH", default="",
                    help="write the waiver-budget report to PATH "
                         "('-' for stdout)")
    ap.add_argument("--no-scope", action="store_true",
                    help="ignore the legacy-module scope config and "
                         "analyze everything")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived findings")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid]}")
        return 0

    only_files = None
    if args.changed_only:
        only_files = _changed_files()
        if only_files is None:
            print("analysis: --changed-only needs git; running full set",
                  file=sys.stderr)

    cache = FindingsCache(args.cache) if args.cache else None

    paths = args.paths or ["src/repro"]
    passes = _PASSES[args.passes]
    t0 = time.monotonic()
    res = analyze_paths(paths, package=args.package, strict=args.strict,
                        apply_scope=not args.no_scope, passes=passes,
                        only_files=only_files, cache=cache)
    elapsed = time.monotonic() - t0
    if cache is not None:
        cache.save()

    text = render_text(res.findings, show_waived=args.show_waived
                       or args.strict)
    if text:
        print(text)

    if args.json:
        payload = render_json(res.findings, meta={
            "files": len(res.files), "passes": list(passes),
            "seconds": round(elapsed, 3)})
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(payload + "\n")

    budget = render_budget(res.findings, res.waiver_maps)
    if args.budget_report == "-":
        print(budget)
    elif args.budget_report:
        with open(args.budget_report, "w", encoding="utf-8") as fh:
            fh.write(budget + "\n")

    active = res.active
    waived = res.waived
    cache_note = (f", cache {cache.hits}/{cache.hits + cache.misses} hit"
                  if cache is not None else "")
    print(f"analysis[{'+'.join(passes)}]: {len(res.files)} files, "
          f"{len(active)} finding(s), {len(waived)} waived, "
          f"{len(res.unused_waivers)} unused waiver(s) "
          f"[{elapsed:.2f}s{cache_note}]")

    if args.strict:
        return 1 if (active or waived or res.unused_waivers) else 0
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
