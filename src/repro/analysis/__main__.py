"""CLI: `python -m repro.analysis [paths...]`.

Exit status 0 = clean (every finding waived with a reason); 1 = unwaived
findings (or, under --strict, ANY findings/waivers).  Also reachable as
`scripts/seclint.py`.
"""

from __future__ import annotations

import argparse
import sys
import time

from .engine import analyze_paths
from .registry import RULES
from .report import render_budget, render_json, render_text


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="seclint",
        description="secrecy-taint + field-arithmetic static analyzer "
                    "for the COPML MPC hot path")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or trees to analyze (default: src/repro)")
    ap.add_argument("--package", default="",
                    help="dotted package context for explicitly-listed "
                         "files (resolves their relative imports), e.g. "
                         "--package repro.core")
    ap.add_argument("--strict", action="store_true",
                    help="treat every waiver (used or unused) as an error")
    ap.add_argument("--json", metavar="PATH", default="",
                    help="write the full findings report as JSON")
    ap.add_argument("--budget-report", metavar="PATH", default="",
                    help="write the waiver-budget report to PATH "
                         "('-' for stdout)")
    ap.add_argument("--no-scope", action="store_true",
                    help="ignore the legacy-module scope config and "
                         "analyze everything")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived findings")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid in sorted(RULES):
            print(f"{rid}  {RULES[rid]}")
        return 0

    paths = args.paths or ["src/repro"]
    t0 = time.monotonic()
    res = analyze_paths(paths, package=args.package, strict=args.strict,
                        apply_scope=not args.no_scope)
    elapsed = time.monotonic() - t0

    text = render_text(res.findings, show_waived=args.show_waived
                       or args.strict)
    if text:
        print(text)

    if args.json:
        payload = render_json(res.findings, meta={
            "files": len(res.files), "seconds": round(elapsed, 3)})
        with open(args.json, "w", encoding="utf-8") as fh:
            fh.write(payload + "\n")

    budget = render_budget(res.findings, res.waiver_maps)
    if args.budget_report == "-":
        print(budget)
    elif args.budget_report:
        with open(args.budget_report, "w", encoding="utf-8") as fh:
            fh.write(budget + "\n")

    active = res.active
    waived = res.waived
    print(f"seclint: {len(res.files)} files, {len(active)} finding(s), "
          f"{len(waived)} waived, {len(res.unused_waivers)} unused "
          f"waiver(s) [{elapsed:.2f}s]")

    if args.strict:
        return 1 if (active or waived or res.unused_waivers) else 0
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
