"""Inline waiver pragmas.

Grammar (one per line, same line as the finding or a standalone comment
line directly above it), written after a comment marker::

    <hash> seclint: allow[SEC001] reason=<free text to end of line>
    <hash> seclint: allow[FLD001,FLD002] reason=<...>

(spelled with a literal ``#``; this docstring avoids the token so the
scanner -- which matches raw source lines -- does not parse its own
documentation as a pragma).  A reason is mandatory -- a pragma without one is itself a finding
(WVR001), as is an unparseable rule list.  `--strict` additionally turns
every waiver (and every unused waiver, WVR002) into an error so
suppressions cannot accumulate silently.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

from .registry import RULES
from .report import Finding

_PRAGMA_RE = re.compile(r"#\s*seclint:\s*(?P<body>.*)$")
_ALLOW_RE = re.compile(
    r"^allow\[(?P<rules>[A-Za-z0-9_,\s]+)\]\s*"
    r"(?:reason\s*=\s*(?P<reason>\S.*))?$"
)


@dataclass
class Waiver:
    rules: tuple
    reason: str
    line: int          # line the pragma text sits on
    applies_to: tuple  # line numbers this waiver covers
    used: bool = False
    consumed_rules: set = field(default_factory=set)


def scan_file(path: str, source: str):
    """Return ({covered_line: Waiver}, [malformed-pragma Findings])."""
    waivers: dict[int, Waiver] = {}
    problems: list[Finding] = []
    lines = source.splitlines()
    for idx, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        body = m.group("body").strip()
        am = _ALLOW_RE.match(body)
        if not am:
            problems.append(Finding(
                "WVR001", f"malformed seclint pragma: {body!r} "
                "(expected `allow[RULE,...] reason=<text>`)", path, idx))
            continue
        rules = tuple(r.strip() for r in am.group("rules").split(",")
                      if r.strip())
        unknown = [r for r in rules if r not in RULES]
        reason = (am.group("reason") or "").strip()
        if not rules or unknown or not reason:
            what = (f"unknown rule ids {unknown}" if unknown
                    else "missing reason=" if not reason else "empty rules")
            problems.append(Finding(
                "WVR001", f"malformed seclint pragma ({what}): {body!r}",
                path, idx))
            continue
        # a pragma on a comment-only line covers the next line; a trailing
        # pragma covers its own line
        own_line = text[:m.start()].strip() != ""
        covered = idx if own_line else idx + 1
        waivers[covered] = Waiver(rules, reason, idx, (covered,))
    return waivers, problems


def apply(findings, waiver_maps):
    """Mark findings waived in place; waiver_maps is {path: {line: Waiver}}."""
    for f in findings:
        per_file = waiver_maps.get(f.path)
        if not per_file:
            continue
        w = per_file.get(f.line)
        if w and f.rule in w.rules:
            f.waived = True
            f.waiver_reason = w.reason
            w.used = True
            w.consumed_rules.add(f.rule)
    return findings


def unused_findings(waiver_maps):
    """WVR002 findings for waivers that never suppressed anything."""
    out = []
    for path in sorted(waiver_maps):
        for line, w in sorted(waiver_maps[path].items()):
            if not w.used:
                out.append(Finding(
                    "WVR002",
                    f"waiver allow[{','.join(w.rules)}] never matched a "
                    "finding", path, w.line))
    return out
