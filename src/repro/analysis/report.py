"""Findings, text/JSON rendering, and the waiver-budget report."""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from .registry import RULES


@dataclass
class Finding:
    rule: str
    message: str
    path: str
    line: int
    col: int = 0
    trace: tuple = field(default_factory=tuple)
    waived: bool = False
    waiver_reason: str = ""

    @property
    def location(self) -> str:
        return f"{self.path}:{self.line}:{self.col}"

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "message": self.message,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "trace": list(self.trace),
            "waived": self.waived,
            "waiver_reason": self.waiver_reason,
        }


def render_text(findings, *, show_waived: bool = False) -> str:
    """One finding per block: location, rule, message, taint trace."""
    lines = []
    for f in findings:
        if f.waived and not show_waived:
            continue
        tag = " (waived: %s)" % f.waiver_reason if f.waived else ""
        lines.append(f"{f.location} {f.rule} {f.message}{tag}")
        for step in f.trace:
            lines.append(f"    trace: {step}")
    return "\n".join(lines)


def render_json(findings, *, meta: dict | None = None) -> str:
    active = [f for f in findings if not f.waived]
    payload = {
        "tool": "seclint",
        "rules": RULES,
        "counts": _counts(findings),
        "findings": [f.to_dict() for f in findings],
        "active": len(active),
    }
    if meta:
        payload.update(meta)
    return json.dumps(payload, indent=2, sort_keys=True)


def _counts(findings) -> dict:
    out: dict = {"active": {}, "waived": {}}
    for f in findings:
        bucket = out["waived" if f.waived else "active"]
        bucket[f.rule] = bucket.get(f.rule, 0) + 1
    return out


def render_budget(findings, waiver_index) -> str:
    """The suppression budget: every waiver in the tree, visible in one place.

    `waiver_index` is {path: {line: Waiver}} as built by waivers.scan_file.
    """
    lines = ["# seclint waiver budget", ""]
    per_rule: dict = {}
    rows = []
    for path in sorted(waiver_index):
        for line in sorted(waiver_index[path]):
            w = waiver_index[path][line]
            for rule in w.rules:
                per_rule[rule] = per_rule.get(rule, 0) + 1
            state = "used" if w.used else "UNUSED"
            rows.append(f"{path}:{line} allow[{','.join(w.rules)}] "
                        f"[{state}] reason: {w.reason}")
    total = sum(per_rule.values())
    lines.append(f"total waivers: {total}")
    for rule in sorted(per_rule):
        lines.append(f"  {rule}: {per_rule[rule]}")
    lines.append("")
    lines.extend(rows if rows else ["(no waivers)"])
    waived = [f for f in findings if f.waived]
    lines.append("")
    lines.append(f"findings suppressed by waivers: {len(waived)}")
    return "\n".join(lines)
