"""Per-file findings cache for the sec pass (``--cache PATH``).

The seclint pass is per-file and depends only on (a) the file itself,
(b) the modules it imports (their annotations and labeled dataclass
fields feed cross-module resolution), and (c) the analyzer code + rule
catalog.  The cache memoizes each analyzed file's pre-waiver findings
keyed on exactly those three inputs:

  * the file's own ``(mtime_ns, size)``,
  * the ``(mtime_ns, size)`` of every one-hop import that resolves to an
    indexed module (annotation changes in a dependency invalidate the
    dependent, which is the only cross-module channel the sec pass has),
  * a global fingerprint over ``src/repro/analysis/*.py`` stats and the
    rule-id catalog (upgrading the analyzer invalidates everything).

Waiver scanning and the comm pass are NOT cached: waiver maps are needed
for `apply()` on every run (and are a cheap regex scan), and commlint is
one AST walk over at most a handful of runtime groups.

The store is a plain JSON file; a missing, corrupt, or stale-format file
degrades to an empty cache.  `save()` is explicit so pure read runs
never touch disk.
"""

from __future__ import annotations

import json
import os

from . import registry
from .report import Finding

_FORMAT = 1


def _stat(path):
    try:
        st = os.stat(path)
    except OSError:
        return None
    return [st.st_mtime_ns, st.st_size]


def _analyzer_fingerprint():
    parts = [f"format={_FORMAT}", "rules=" + ",".join(sorted(registry.RULES))]
    pkg_dir = os.path.dirname(os.path.abspath(__file__))
    try:
        names = sorted(fn for fn in os.listdir(pkg_dir)
                       if fn.endswith(".py"))
    except OSError:
        names = []
    for fn in names:
        parts.append(f"{fn}:{_stat(os.path.join(pkg_dir, fn))}")
    return "|".join(parts)


def _dep_paths(mi, index):
    """Paths of the one-hop imports that resolve inside the index."""
    dotted = set(mi.imports.values())
    for full in mi.symbols.values():
        dotted.add(full)
        dotted.add(full.rsplit(".", 1)[0])
    out = set()
    for name in dotted:
        dep = index.modules.get(name)
        if dep is not None and dep.path != mi.path:
            out.add(os.path.abspath(dep.path))
    return sorted(out)


class FindingsCache:
    """Findings memo for `analyze_paths(..., cache=...)`."""

    def __init__(self, path):
        self.path = path
        self.hits = 0
        self.misses = 0
        self._dirty = False
        self._files = {}
        fingerprint = _analyzer_fingerprint()
        try:
            with open(path, "r", encoding="utf-8") as fh:
                data = json.load(fh)
            if data.get("fingerprint") == fingerprint:
                self._files = data.get("files", {})
        except (OSError, ValueError):
            pass
        self._fingerprint = fingerprint

    def get(self, mi, index):
        """Cached pre-waiver findings for `mi`, or None on any mismatch."""
        key = os.path.abspath(mi.path)
        entry = self._files.get(key)
        if entry is None or entry.get("stat") != _stat(key):
            self.misses += 1
            return None
        deps = _dep_paths(mi, index)
        if entry.get("deps") != {d: _stat(d) for d in deps}:
            self.misses += 1
            return None
        self.hits += 1
        return [Finding(**d) for d in entry["findings"]]

    def put(self, mi, index, findings):
        key = os.path.abspath(mi.path)
        self._files[key] = {
            "stat": _stat(key),
            "deps": {d: _stat(d) for d in _dep_paths(mi, index)},
            "findings": [
                {"rule": f.rule, "message": f.message, "path": f.path,
                 "line": f.line, "col": f.col} for f in findings],
        }
        self._dirty = True

    def save(self):
        if not self._dirty:
            return
        payload = {"fingerprint": self._fingerprint, "files": self._files}
        tmp = self.path + ".tmp"
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump(payload, fh)
        os.replace(tmp, self.path)
