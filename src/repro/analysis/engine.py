"""Two-pass AST analysis: global index, then per-function taint + rules.

Pass 1 indexes every module under the analysis roots: import aliases,
functions/methods with their label annotations (`Share`, `Coded`,
`Public`, `SecretRand`, `Opened` from core/labels.py), and classes with
labeled fields (`CopmlState.w_shares: Share`, ...).

Pass 2 walks each function intra-procedurally.  Taint enters through
parameter annotations, labeled dataclass fields, and registered source
calls; it moves through expressions by the effect table in registry.py;
rules fire where a secret reaches a host escape (SEC001), steers Python
control flow (SEC002), or leaves through an unregistered module
(SEC003), and where field-domain values meet raw operators (FLD001),
unreduced narrowing casts (FLD002), floats (FLD003), or foreign modulus
literals (FLD004).  Calls are resolved through annotations and the
registry rather than followed -- that keeps the analysis sound at
function boundaries without inter-procedural blowup: whatever a callee
really does, its annotated signature is the contract seclint enforces.
"""

from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field as dc_field

from . import commlint
from . import scope as scope_mod
from . import waivers as waivers_mod
from .registry import (
    ANNOT_LABELS,
    ARITH_METHODS,
    CODED,
    ESCAPE_METHODS,
    FIELD,
    FLOAT_DTYPES,
    KNOWN_MODULES,
    META_ATTRS,
    META_METHODS,
    NARROW_DTYPES,
    P_VALUE,
    RAND,
    REDUCE_SITES,
    REDUCED,
    SAFE_ROOTS,
    SECRET,
    SHARE,
    SMALL_MOD_FLOOR,
    fld_exempt,
    lookup_effect,
)
from .report import Finding

_TRACE_CAP = 6
_RAW_OPS = (ast.Add, ast.Sub, ast.Mult, ast.MatMult, ast.Pow)


# --------------------------------------------------------------------------
# taint values
# --------------------------------------------------------------------------

class Taint:
    __slots__ = ("labels", "trace")

    def __init__(self, labels=frozenset(), trace=()):
        self.labels = frozenset(labels)
        self.trace = tuple(trace)[:_TRACE_CAP]

    @property
    def secret(self):
        return bool(self.labels & SECRET)

    def with_step(self, step):
        if len(self.trace) >= _TRACE_CAP:
            return self
        return Taint(self.labels, self.trace + (step,))

    def __repr__(self):  # pragma: no cover -- debugging aid
        return f"Taint({sorted(self.labels)})"


PLAIN = Taint()


def _union(taints):
    labels = frozenset().union(*(t.labels for t in taints)) if taints \
        else frozenset()
    trace = ()
    for t in taints:
        if t.trace and (not trace or (t.secret and len(t.trace) > len(trace))):
            trace = t.trace
    return Taint(labels, trace)


def _propagate(taints):
    """Union, but `reduced` survives only if every field arg was reduced."""
    out = _union(taints)
    if any(FIELD in t.labels and REDUCED not in t.labels for t in taints):
        out = Taint(out.labels - {REDUCED}, out.trace)
    return out


# --------------------------------------------------------------------------
# pass 1: index
# --------------------------------------------------------------------------

def _ann_labels(node):
    """(labels, declassify) from a label annotation, or None if unlabeled."""
    found = set()
    declassify = False
    hit = False
    for sub in ast.walk(node):
        name = None
        if isinstance(sub, ast.Name):
            name = sub.id
        elif isinstance(sub, ast.Attribute):
            name = sub.attr
        if name in ANNOT_LABELS:
            hit = True
            found |= ANNOT_LABELS[name]
            declassify = declassify or name == "Opened"
    return (frozenset(found), declassify) if hit else None


def _ann_type_name(node):
    """Bare dotted type name of an annotation ('CopmlState', 'm.C'), or None."""
    parts = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None


@dataclass
class FuncInfo:
    name: str
    qualname: str
    module: str
    node: object
    params: list = dc_field(default_factory=list)  # (name, labels, type_raw)
    return_labels: object = None    # frozenset | None
    return_declassify: bool = False
    return_type_raw: str = ""
    return_type: str = ""           # resolved global class key


@dataclass
class ClassInfo:
    name: str
    module: str
    key: str
    fields: dict = dc_field(default_factory=dict)   # name -> labels
    methods: dict = dc_field(default_factory=dict)  # name -> FuncInfo
    bases_raw: list = dc_field(default_factory=list)


@dataclass
class ModuleInfo:
    path: str
    modname: str
    tree: object
    source: str
    imports: dict = dc_field(default_factory=dict)   # alias -> module dotted
    symbols: dict = dc_field(default_factory=dict)   # name -> full dotted
    functions: dict = dc_field(default_factory=dict)  # name -> FuncInfo
    classes: dict = dc_field(default_factory=dict)    # name -> ClassInfo


def _func_info(node, modname, qualprefix=""):
    fi = FuncInfo(node.name, qualprefix + node.name, modname, node)
    a = node.args
    every = list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)
    for arg in every:
        labels = _ann_labels(arg.annotation) if arg.annotation else None
        traw = _ann_type_name(arg.annotation) if arg.annotation else None
        fi.params.append((arg.arg, labels, traw))
    for va in (a.vararg, a.kwarg):
        if va is not None:
            fi.params.append((va.arg, None, None))
    if node.returns is not None:
        spec = _ann_labels(node.returns)
        if spec is not None:
            fi.return_labels, fi.return_declassify = spec
        fi.return_type_raw = _ann_type_name(node.returns) or ""
    return fi


def _index_module(path, source, modname):
    tree = ast.parse(source, filename=path)
    mi = ModuleInfo(path, modname, tree, source)
    pkg_parts = modname.split(".")[:-1]

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for al in node.names:
                mi.imports[al.asname or al.name.split(".")[0]] = (
                    al.name if al.asname else al.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.level:
                base_parts = modname.split(".")
                base = ".".join(base_parts[:len(base_parts) - node.level])
                if node.module:
                    base = f"{base}.{node.module}" if base else node.module
            else:
                base = node.module or ""
            for al in node.names:
                if al.name == "*":
                    continue
                full = f"{base}.{al.name}" if base else al.name
                mi.symbols[al.asname or al.name] = full

    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            mi.functions[node.name] = _func_info(node, modname)
        elif isinstance(node, ast.ClassDef):
            ci = ClassInfo(node.name, modname, f"{modname}.{node.name}")
            for b in node.bases:
                traw = _ann_type_name(b)
                if traw:
                    ci.bases_raw.append(traw)
            for item in node.body:
                if isinstance(item, ast.AnnAssign) and isinstance(
                        item.target, ast.Name):
                    spec = _ann_labels(item.annotation)
                    if spec is not None:
                        ci.fields[item.target.id] = spec[0]
                elif isinstance(item, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                    ci.methods[item.name] = _func_info(
                        item, modname, f"{node.name}.")
            mi.classes[node.name] = ci
    del pkg_parts
    return mi


class ProjectIndex:
    def __init__(self):
        self.modules = {}    # modname -> ModuleInfo
        self.functions = {}  # "mod.func" -> FuncInfo
        self.classes = {}    # "mod.Class" -> ClassInfo

    def add(self, mi):
        self.modules[mi.modname] = mi
        for name, fi in mi.functions.items():
            self.functions[f"{mi.modname}.{name}"] = fi
        for name, ci in mi.classes.items():
            self.classes[ci.key] = ci

    def resolve_class(self, mi, raw):
        """Resolve a raw type name in module `mi` to a global class key."""
        if not raw:
            return ""
        head, _, rest = raw.partition(".")
        if not rest and head in mi.classes:
            return mi.classes[head].key
        if head in mi.symbols:
            cand = mi.symbols[head] + (("." + rest) if rest else "")
            return cand if cand in self.classes else ""
        if head in mi.imports and rest:
            cand = f"{mi.imports[head]}.{rest}"
            return cand if cand in self.classes else ""
        cand = f"{mi.modname}.{raw}"
        return cand if cand in self.classes else ""

    def finalize(self):
        # inheritance: pull unshadowed fields/methods down from bases
        for _ in range(3):  # shallow hierarchies; a few rounds suffice
            for ci in self.classes.values():
                mi = self.modules.get(ci.module)
                if mi is None:
                    continue
                for raw in ci.bases_raw:
                    key = self.resolve_class(mi, raw)
                    base = self.classes.get(key)
                    if base is None:
                        continue
                    for fname, labels in base.fields.items():
                        ci.fields.setdefault(fname, labels)
                    for mname, fi in base.methods.items():
                        ci.methods.setdefault(mname, fi)
        # resolve return/param type names to class keys
        all_funcs = list(self.functions.values())
        for ci in self.classes.values():
            all_funcs.extend(ci.methods.values())
        for fi in all_funcs:
            mi = self.modules.get(fi.module)
            if mi is None:
                continue
            fi.return_type = self.resolve_class(mi, fi.return_type_raw)


# --------------------------------------------------------------------------
# pass 2: per-function taint + rules
# --------------------------------------------------------------------------

class FunctionAnalyzer:
    def __init__(self, index, mi, findings, *, enclosing_class=None):
        self.index = index
        self.mi = mi
        self.findings = findings
        self.enclosing_class = enclosing_class  # ClassInfo | None
        self.env = {}    # name -> Taint ("self.attr" keys for self stores)
        self.types = {}  # name -> global class key
        self.exempt = fld_exempt(mi.path)
        self._sanctioned = set()  # ids of BinOps under a `% P` reduction

    # -- helpers ----------------------------------------------------------

    def _loc(self, node):
        return f"{self.mi.path}:{node.lineno}"

    def emit(self, rule, message, node, trace=()):
        self.findings.append(Finding(
            rule, message, self.mi.path, node.lineno,
            getattr(node, "col_offset", 0), tuple(trace)))

    def resolve_dotted(self, node):
        parts = []
        cur = node
        while isinstance(cur, ast.Attribute):
            parts.append(cur.attr)
            cur = cur.value
        if not isinstance(cur, ast.Name):
            return None
        parts.append(cur.id)
        parts.reverse()
        root, rest = parts[0], parts[1:]
        if root in self.env and root not in self.mi.imports:
            return None  # a local value shadows any same-named import
        if root in self.mi.imports:
            return ".".join([self.mi.imports[root]] + rest)
        if root in self.mi.symbols:
            return ".".join([self.mi.symbols[root]] + rest)
        if root in ("repro", "jax", "numpy") or root in KNOWN_MODULES:
            return ".".join(parts)
        return None

    def _is_field_p(self, node):
        if isinstance(node, ast.Constant):
            return node.value == P_VALUE
        dotted = self.resolve_dotted(node)
        if dotted and (dotted == "repro.core.field.P"
                       or dotted.endswith("field.P")):
            return True
        return False

    def _seed_params(self, fi):
        for name, labels, traw in fi.params:
            if labels is not None:
                lab, _declass = labels
                self.env[name] = Taint(
                    lab, (f"param `{name}` of {fi.qualname} "
                          f"({self.mi.path})",))
            else:
                self.env[name] = PLAIN
                key = self.index.resolve_class(self.mi, traw or "")
                if key:
                    self.types[name] = key
        if self.enclosing_class is not None and fi.params:
            first = fi.params[0][0]
            if first in ("self", "cls"):
                self.types[first] = self.enclosing_class.key

    # -- driver -----------------------------------------------------------

    def run_function(self, fi):
        self._seed_params(fi)
        self.walk_block(fi.node.body)

    def run_module_level(self, body):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                continue
            self.stmt(stmt)

    # -- statements -------------------------------------------------------

    def walk_block(self, stmts):
        for s in stmts:
            self.stmt(s)

    def stmt(self, node):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fi = _func_info(node, self.mi.modname)
            child = FunctionAnalyzer(self.index, self.mi, self.findings,
                                     enclosing_class=self.enclosing_class)
            child.env = dict(self.env)
            child.types = dict(self.types)
            child._seed_params(fi)
            child.walk_block(node.body)
            self.env[node.name] = PLAIN
        elif isinstance(node, ast.ClassDef):
            pass  # nested classes: not part of the protocol surface
        elif isinstance(node, ast.Assign):
            t = self.eval(node.value)
            ty = self.type_of(node.value)
            for tgt in node.targets:
                self.bind(tgt, t, ty, node)
        elif isinstance(node, ast.AnnAssign):
            spec = _ann_labels(node.annotation)
            if node.value is not None:
                t = self.eval(node.value)
                ty = self.type_of(node.value)
            else:
                t, ty = PLAIN, ""
            if spec is not None:
                lab, _declass = spec
                t = Taint(lab, (f"annotated at {self._loc(node)}",))
                ty = ""
            elif node.value is None:
                return
            else:
                key = self.index.resolve_class(
                    self.mi, _ann_type_name(node.annotation) or "")
                ty = key or ty
            self.bind(node.target, t, ty, node)
        elif isinstance(node, ast.AugAssign):
            cur = self.eval(node.target) if not isinstance(
                node.target, ast.Name) else self.env.get(
                node.target.id, PLAIN)
            val = self.eval(node.value)
            res = self._binop_effect(node, node.op, cur, val,
                                     node.value)
            self.bind(node.target, res, "", node)
        elif isinstance(node, ast.If):
            t = self.eval(node.test)
            if t.secret:
                self.emit("SEC002",
                          "Python `if` on a secret-tainted condition",
                          node, t.trace)
            self._branch(node.body, node.orelse)
        elif isinstance(node, ast.While):
            t = self.eval(node.test)
            if t.secret:
                self.emit("SEC002",
                          "Python `while` on a secret-tainted condition",
                          node, t.trace)
            self._loop_body(node.body, node.orelse)
            t2 = self.eval(node.test)
            if t2.secret and not t.secret:
                self.emit("SEC002",
                          "Python `while` on a secret-tainted condition",
                          node, t2.trace)
        elif isinstance(node, ast.For):
            it = self.eval(node.iter)
            self.bind(node.target, it, "", node)
            self._loop_body(node.body, node.orelse)
        elif isinstance(node, ast.Try):
            self.walk_block(node.body)
            for h in node.handlers:
                if h.name:
                    self.env[h.name] = PLAIN
                self.walk_block(h.body)
            self.walk_block(node.orelse)
            self.walk_block(node.finalbody)
        elif isinstance(node, ast.With):
            for item in node.items:
                t = self.eval(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, t, "", node)
            self.walk_block(node.body)
        elif isinstance(node, (ast.Return, ast.Expr)):
            if node.value is not None:
                self.eval(node.value)
        elif isinstance(node, ast.Assert):
            self.eval(node.test)
            if node.msg is not None:
                self.eval(node.msg)
        elif isinstance(node, ast.Raise):
            if node.exc is not None:
                self.eval(node.exc)
            if node.cause is not None:
                self.eval(node.cause)
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.env.pop(tgt.id, None)
        # Pass / Import / Global / Nonlocal / Break / Continue: nothing

    def _branch(self, body, orelse):
        save_env, save_ty = dict(self.env), dict(self.types)
        self.walk_block(body)
        after_env, after_ty = self.env, self.types
        self.env, self.types = dict(save_env), dict(save_ty)
        self.walk_block(orelse)
        self._merge(after_env, after_ty)

    def _loop_body(self, body, orelse):
        save_env, save_ty = dict(self.env), dict(self.types)
        self.walk_block(body)
        self.walk_block(body)  # second pass: loop-carried taint
        self.walk_block(orelse)
        self._merge(save_env, save_ty)

    def _merge(self, other_env, other_ty):
        for name, t in other_env.items():
            mine = self.env.get(name)
            self.env[name] = _union([mine, t]) if mine is not None else t
        for name, ty in other_ty.items():
            if self.types.get(name, ty) != ty:
                del self.types[name]
            else:
                self.types.setdefault(name, ty)

    def bind(self, target, taint, ty, node):
        if isinstance(target, ast.Name):
            if taint.secret or FIELD in taint.labels:
                taint = taint.with_step(
                    f"assigned to `{target.id}` at {self._loc(node)}")
            self.env[target.id] = taint
            if ty:
                self.types[target.id] = ty
            else:
                self.types.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                el_t = taint
                if isinstance(el, ast.Starred):
                    el = el.value
                self.bind(el, el_t, "", node)
        elif isinstance(target, ast.Attribute):
            base = target.value
            if isinstance(base, ast.Name) and base.id in ("self", "cls"):
                self.env[f"{base.id}.{target.attr}"] = taint
            else:
                self._store_into_base(base, taint)
        elif isinstance(target, ast.Subscript):
            self.eval(target.slice)
            self._store_into_base(target.value, taint)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, taint, "", node)

    def _store_into_base(self, base, taint):
        """x[i] = v / x.attr = v: union the value's labels into x."""
        cur = base
        while isinstance(cur, (ast.Subscript, ast.Attribute)):
            cur = cur.value
        if isinstance(cur, ast.Name):
            old = self.env.get(cur.id, PLAIN)
            labels = old.labels | taint.labels
            # a store of an unreduced field value poisons canonicity
            if FIELD in taint.labels and REDUCED not in taint.labels:
                labels -= {REDUCED}
            self.env[cur.id] = Taint(labels, taint.trace or old.trace)

    # -- types ------------------------------------------------------------

    def type_of(self, node):
        if isinstance(node, ast.Name):
            return self.types.get(node.id, "")
        if isinstance(node, ast.Call):
            eff = self._call_effect_only(node)
            return eff or ""
        return ""

    def _call_effect_only(self, node):
        """Return type (class key) a call produces, without re-analysis."""
        f = node.func
        dotted = self.resolve_dotted(f)
        if dotted:
            if dotted in self.index.classes:
                return dotted
            fi = self.index.functions.get(dotted)
            if fi is not None:
                return fi.return_type
            eff = lookup_effect(dotted)
            if eff and eff["kind"] == "replace" and node.args:
                return self.type_of(node.args[0])
            return ""
        if isinstance(f, ast.Name):
            if f.id in self.mi.classes:
                return self.mi.classes[f.id].key
            fi = self.mi.functions.get(f.id)
            if fi is not None:
                return fi.return_type
            return ""
        if isinstance(f, ast.Attribute):
            fi = self._method_info(f)
            if fi is not None:
                return fi.return_type
        return ""

    def _method_info(self, attr_node):
        """FuncInfo for `obj.method` when obj's class is known."""
        base = attr_node.value
        key = ""
        if isinstance(base, ast.Name):
            key = self.types.get(base.id, "")
        elif isinstance(base, ast.Attribute) and isinstance(
                base.value, ast.Name) and base.value.id in ("self", "cls"):
            key = ""  # self.attr types are not tracked
        ci = self.index.classes.get(key)
        if ci is not None:
            return ci.methods.get(attr_node.attr)
        return None

    # -- expressions ------------------------------------------------------

    def eval(self, node):
        if node is None:
            return PLAIN
        if isinstance(node, ast.Constant):
            return PLAIN
        if isinstance(node, ast.Name):
            return self.env.get(node.id, PLAIN)
        if isinstance(node, ast.Attribute):
            return self._attribute(node)
        if isinstance(node, ast.Subscript):
            self.eval(node.slice)
            return self.eval(node.value)
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, ast.Mod) and self._is_field_p(node.right):
                # sanction the left subtree BEFORE descending into it, so
                # `(a * b) % field.P` never flags the inner product
                for sub in ast.walk(node.left):
                    if isinstance(sub, ast.BinOp):
                        self._sanctioned.add(id(sub))
            lt = self.eval(node.left)
            rt = self.eval(node.right)
            return self._binop_effect(node, node.op, lt, rt, node.right,
                                      left_node=node.left)
        if isinstance(node, ast.BoolOp):
            return _union([self.eval(v) for v in node.values])
        if isinstance(node, ast.Compare):
            return _union([self.eval(node.left)]
                          + [self.eval(c) for c in node.comparators])
        if isinstance(node, ast.UnaryOp):
            return self.eval(node.operand)
        if isinstance(node, ast.IfExp):
            return _union([self.eval(node.test), self.eval(node.body),
                           self.eval(node.orelse)])
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return _union([self.eval(e) for e in node.elts])
        if isinstance(node, ast.Dict):
            vals = [self.eval(k) for k in node.keys if k is not None]
            vals += [self.eval(v) for v in node.values]
            return _union(vals)
        if isinstance(node, ast.JoinedStr):
            return _union([self.eval(v.value) for v in node.values
                           if isinstance(v, ast.FormattedValue)])
        if isinstance(node, ast.FormattedValue):
            return self.eval(node.value)
        if isinstance(node, ast.Starred):
            return self.eval(node.value)
        if isinstance(node, ast.Lambda):
            child = FunctionAnalyzer(self.index, self.mi, self.findings,
                                     enclosing_class=self.enclosing_class)
            child.env = dict(self.env)
            child.types = dict(self.types)
            for arg in (list(node.args.posonlyargs) + list(node.args.args)
                        + list(node.args.kwonlyargs)):
                child.env[arg.arg] = PLAIN
            child.eval(node.body)
            return PLAIN
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            for gen in node.generators:
                it = self.eval(gen.iter)
                self.bind(gen.target, it, "", node)
                for cond in gen.ifs:
                    self.eval(cond)
            if isinstance(node, ast.DictComp):
                return _union([self.eval(node.key), self.eval(node.value)])
            return self.eval(node.elt)
        if isinstance(node, ast.NamedExpr):
            t = self.eval(node.value)
            self.bind(node.target, t, self.type_of(node.value), node)
            return t
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.eval(node.value)
        if isinstance(node, ast.Yield):
            return self.eval(node.value) if node.value else PLAIN
        if isinstance(node, ast.Slice):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self.eval(part)
            return PLAIN
        return PLAIN

    def _attribute(self, node):
        # module-path attributes (field.P, jnp.int32) are values, no taint
        if self.resolve_dotted(node) is not None:
            return PLAIN
        base = node.value
        if isinstance(base, ast.Name) and base.id in ("self", "cls"):
            stored = self.env.get(f"{base.id}.{node.attr}")
            if stored is not None:
                return stored
            if self.enclosing_class is not None:
                labels = self.enclosing_class.fields.get(node.attr)
                if labels is not None:
                    return Taint(labels, (
                        f"{self.enclosing_class.name}.{node.attr} "
                        f"labeled field",))
            return PLAIN
        base_t = self.eval(base)
        ty = self.type_of(base)
        ci = self.index.classes.get(ty)
        if ci is not None:
            labels = ci.fields.get(node.attr)
            if labels is not None:
                return Taint(labels, (f"{ci.name}.{node.attr} labeled field "
                                      f"(read at {self._loc(node)})",))
            return PLAIN
        if node.attr in META_ATTRS:
            return PLAIN
        return base_t

    # -- operators --------------------------------------------------------

    def _binop_effect(self, node, op, lt, rt, right_node, left_node=None):
        loc_labels = lt.labels | rt.labels
        trace = _union([lt, rt]).trace
        if isinstance(op, ast.Mod):
            if self._is_field_p(right_node):
                # the lazy-reduction idiom: `(expr) % field.P` sanctions the
                # whole left subtree (magnitude is on the author)
                if left_node is not None:
                    for sub in ast.walk(left_node):
                        if isinstance(sub, ast.BinOp):
                            self._sanctioned.add(id(sub))
                return Taint(loc_labels | {FIELD, REDUCED}, trace)
            if isinstance(right_node, ast.Constant) and isinstance(
                    right_node.value, int) \
                    and right_node.value >= SMALL_MOD_FLOOR \
                    and right_node.value != P_VALUE:
                self.emit("FLD004",
                          f"modulus literal {right_node.value} is not "
                          "field.P", node, trace)
        if isinstance(op, ast.Div) and FIELD in loc_labels \
                and not self.exempt:
            self.emit("FLD003",
                      "true division produces floats from a field-domain "
                      "value", node, trace)
        if isinstance(op, _RAW_OPS + (ast.Mod,)) and FIELD in loc_labels \
                and not self.exempt and id(node) not in self._sanctioned:
            opname = type(op).__name__
            self.emit("FLD001",
                      f"raw `{opname}` on a field-domain value outside "
                      "core/field.py / kernels wrappers "
                      "(use field.add/mul/matmul or reduce with % field.P)",
                      node, trace)
        if FIELD in loc_labels and not self.exempt:
            for side in (left_node, right_node):
                if isinstance(side, ast.Constant) and isinstance(
                        side.value, float):
                    self.emit("FLD003",
                              "float literal combined with a field-domain "
                              "value", node, trace)
                    break
        return Taint(loc_labels - {REDUCED}, trace)

    # -- calls ------------------------------------------------------------

    def _dtype_name(self, node):
        if isinstance(node, ast.Constant) and isinstance(node.value, str):
            return node.value
        if isinstance(node, ast.Attribute):
            return node.attr
        if isinstance(node, ast.Name):
            return node.id
        return ""

    def _call(self, node):
        f = node.func
        dotted = self.resolve_dotted(f)
        if dotted in REDUCE_SITES:
            # barrett_reduce/fold26 ARE the reduction: sanction raw
            # arithmetic in the argument subtree, same as `% field.P`
            for a in list(node.args) + [k.value for k in node.keywords]:
                for sub in ast.walk(a):
                    if isinstance(sub, ast.BinOp):
                        self._sanctioned.add(id(sub))
        arg_taints = [self.eval(a) for a in node.args]
        arg_taints += [self.eval(k.value) for k in node.keywords]
        if dotted is not None:
            return self._apply_dotted(dotted, arg_taints, node)

        if isinstance(f, ast.Name):
            name = f.id
            if name in self.mi.classes:
                return _propagate(arg_taints)  # instance carries no labels
            fi = self.mi.functions.get(name)
            if fi is not None:
                return self._apply_funcinfo(fi, arg_taints, node, name)
            if name in ("print", "int", "float", "bool"):
                return self._apply_registry(
                    {"kind": "escape"}, f"builtins.{name}",
                    arg_taints, node)
            if name in ("len", "id", "hash", "isinstance", "hasattr",
                        "getattr", "type", "repr", "str"):
                return PLAIN if name in ("len", "id", "isinstance",
                                         "hasattr", "type") \
                    else _union(arg_taints)
            return _propagate(arg_taints)  # local callable / builtin misc

        if isinstance(f, ast.Attribute):
            return self._method_call(f, arg_taints, node)

        self.eval(f)
        return _propagate(arg_taints)

    def _apply_dotted(self, dotted, arg_taints, node):
        fi = self.index.functions.get(dotted)
        if fi is not None:
            return self._apply_funcinfo(fi, arg_taints, node, dotted)
        if dotted in self.index.classes:
            return _propagate(arg_taints)
        eff = lookup_effect(dotted)
        if eff is not None:
            return self._apply_registry(eff, dotted, arg_taints, node)
        root = dotted.split(".", 1)[0]
        u = _union(arg_taints)
        if root not in SAFE_ROOTS and u.secret:
            self.emit("SEC003",
                      f"secret-tainted value passed to unregistered "
                      f"external callable `{dotted}` (no sanctioned sink "
                      "registered for this module)", node, u.trace)
            return PLAIN
        return _propagate(arg_taints)

    def _apply_funcinfo(self, fi, arg_taints, node, display):
        if fi.return_declassify:
            return Taint((), (f"declassified by `{display}` "
                              f"at {self._loc(node)}",))
        if fi.return_labels is not None:
            labels = fi.return_labels
            step = (f"`{display}() -> "
                    f"{'|'.join(sorted(labels)) or 'opened'}` "
                    f"at {self._loc(node)}")
            carried = _union(arg_taints)
            return Taint(labels | (carried.labels & SECRET),
                         carried.trace[-_TRACE_CAP + 1:] + (step,))
        return _propagate(arg_taints)

    def _apply_registry(self, eff, dotted, arg_taints, node):
        kind = eff["kind"]
        u = _union(arg_taints)
        loc = self._loc(node)
        if kind == "source":
            labels = eff["labels"] | (u.labels & SECRET)
            return Taint(labels, u.trace + (f"secret source `{dotted}` "
                                            f"at {loc}",))
        if kind == "open":
            return Taint((u.labels - {SHARE, RAND}) | {FIELD, REDUCED},
                         u.trace + (f"opened via `{dotted}` at {loc}",))
        if kind == "decode":
            return Taint((u.labels - {CODED}) | {FIELD, REDUCED},
                         u.trace + (f"decoded via `{dotted}` at {loc}",))
        if kind == "declassify":
            return Taint((), (f"declassified via `{dotted}` at {loc}",))
        if kind == "fieldop":
            return Taint(frozenset({FIELD, REDUCED}) | (u.labels & SECRET),
                         u.trace)
        if kind == "dequant":
            return Taint(u.labels - {FIELD, REDUCED}, u.trace)
        if kind == "public":
            return Taint({FIELD, REDUCED}, ())
        if kind == "plain":
            return PLAIN
        if kind == "escape":
            if u.secret:
                self.emit("SEC001",
                          f"secret-tainted value reaches host escape "
                          f"`{dotted}`", node, u.trace)
            return PLAIN
        if kind == "replace":
            return _propagate(arg_taints)
        return _propagate(arg_taints)

    def _method_call(self, f, arg_taints, node):
        fi = self._method_info(f)
        obj_t = self.eval(f.value)
        if fi is not None:
            return self._apply_funcinfo(fi, [obj_t] + arg_taints, node,
                                        fi.qualname)
        attr = f.attr
        if attr in ESCAPE_METHODS:
            if obj_t.secret:
                self.emit("SEC001",
                          f"secret-tainted value reaches host escape "
                          f"`.{attr}()`", node, obj_t.trace)
            return PLAIN
        if attr == "astype":
            dt = ""
            if node.args:
                dt = self._dtype_name(node.args[0])
            for k in node.keywords:
                if k.arg == "dtype":
                    dt = self._dtype_name(k.value)
            if not self.exempt and FIELD in obj_t.labels:
                if dt in NARROW_DTYPES and REDUCED not in obj_t.labels:
                    self.emit("FLD002",
                              f"narrowing cast `.astype({dt})` on a field "
                              "value not dominated by `% field.P`",
                              node, obj_t.trace)
                if dt in FLOAT_DTYPES:
                    self.emit("FLD003",
                              f"float cast `.astype({dt})` on a "
                              "field-domain value", node, obj_t.trace)
            return obj_t
        if attr in META_METHODS:
            return PLAIN
        if attr in ARITH_METHODS:
            out = _union([obj_t] + arg_taints)
            return Taint(out.labels - {REDUCED}, out.trace)
        return _propagate([obj_t] + arg_taints)


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

@dataclass
class AnalysisResult:
    findings: list
    waiver_maps: dict
    files: list
    unused_waivers: list

    @property
    def active(self):
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self):
        return [f for f in self.findings if f.waived]


def _iter_py_files(path):
    if os.path.isfile(path):
        yield path, True  # explicit file: bypass scope filtering
        return
    for dirpath, dirnames, filenames in os.walk(path):
        dirnames[:] = [d for d in sorted(dirnames)
                       if d not in ("__pycache__", ".git")]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn), False


def _modname_for(path, package=""):
    stem = os.path.splitext(os.path.basename(path))[0]
    if package:
        return f"{package}.{stem}" if stem != "__init__" else package
    parts = [stem] if stem != "__init__" else []
    d = os.path.dirname(os.path.abspath(path))
    while os.path.isfile(os.path.join(d, "__init__.py")):
        parts.insert(0, os.path.basename(d))
        d = os.path.dirname(d)
    return ".".join(parts) if parts else stem


def _analyze_module_sec(index, mi):
    """The per-file seclint pass; returns this module's findings.

    Self-contained (depends only on the module + the finalized index) so
    results can be memoized by a FindingsCache keyed on file stats."""
    findings: list[Finding] = []
    top = FunctionAnalyzer(index, mi, findings)
    top.run_module_level(mi.tree.body)
    for fi in mi.functions.values():
        fa = FunctionAnalyzer(index, mi, findings)
        fa.run_function(fi)
    for ci in mi.classes.values():
        for fi in ci.methods.values():
            if fi.module != mi.modname:  # inherited: analyzed at origin
                continue
            fa = FunctionAnalyzer(index, mi, findings,
                                  enclosing_class=ci)
            fa.run_function(fi)
    return findings


def analyze_paths(paths, *, package="", strict=False, apply_scope=True,
                  passes=("sec", "comm"), only_files=None, cache=None):
    """Analyze files/trees; returns an AnalysisResult.

    `package` forces the dotted package context of explicitly-listed
    files (so relative imports in tmp copies of protocol modules resolve
    against the registry).  Directory walks honour the scope config
    unless `apply_scope` is False; explicitly-listed files are always
    analyzed.

    `passes` selects the rule families: "sec" (seclint taint + field
    rules) and/or "comm" (commlint choreography rules).  `only_files`
    (absolute paths) restricts which files are *analyzed* -- everything
    is still indexed, so cross-module resolution and commlint's
    worker/session group discovery see the whole tree (this backs
    --changed-only).  `cache` is an optional FindingsCache memoizing the
    per-file sec pass across runs.
    """
    index = ProjectIndex()
    findings: list[Finding] = []
    selected = []  # (ModuleInfo, analyze?)
    for root in paths:
        for path, explicit in _iter_py_files(root):
            try:
                with open(path, "r", encoding="utf-8") as fh:
                    source = fh.read()
                mi = _index_module(path, source,
                                   _modname_for(path,
                                                package if explicit else ""))
            except (SyntaxError, UnicodeDecodeError) as exc:
                findings.append(Finding(
                    "WVR001", f"unparseable file: {exc}", path,
                    getattr(exc, "lineno", 1) or 1))
                continue
            index.add(mi)
            run = explicit or not apply_scope or scope_mod.in_scope(path)
            if run and only_files is not None:
                run = os.path.abspath(path) in only_files
            selected.append((mi, run))
    index.finalize()

    waiver_maps = {}
    for mi, run in selected:
        if not run:
            continue
        wmap, problems = waivers_mod.scan_file(mi.path, mi.source)
        waiver_maps[mi.path] = wmap
        findings.extend(problems)
        if "sec" not in passes:
            continue
        cached = cache.get(mi, index) if cache is not None else None
        if cached is not None:
            findings.extend(cached)
            continue
        file_findings = _analyze_module_sec(index, mi)
        if cache is not None:
            cache.put(mi, index, file_findings)
        findings.extend(file_findings)

    if "comm" in passes:
        findings.extend(commlint.collect(
            index, [mi.path for mi, run in selected if run]))

    # dedup (loop fixpoints walk bodies twice) and stable order
    seen = set()
    unique = []
    for f in findings:
        key = (f.rule, f.path, f.line, f.col, f.message)
        if key not in seen:
            seen.add(key)
            unique.append(f)
    unique.sort(key=lambda f: (f.path, f.line, f.col, f.rule))

    waivers_mod.apply(unique, waiver_maps)
    unused = waivers_mod.unused_findings(waiver_maps)
    if strict:
        unique.extend(unused)
    return AnalysisResult(unique, waiver_maps, [m.path for m, r in selected
                                               if r], unused)
