"""Production training loop: pjit + checkpoint/restart + secure aggregation.

Runs on whatever mesh the host provides (launch/train.py wires the
production mesh); the same code path is what the 512-device dry-run lowers.

Fault tolerance:
  * checkpoint every `ckpt_every` steps (async, atomic-rename manifests);
  * restart picks up the newest complete step and replays the deterministic
    data stream from there (data/pipeline.py is keyed by step);
  * on a changed device count, restore() re-places leaves against the new
    mesh (elastic re-mesh);
  * optional COPML-coded secure gradient aggregation across the data axis
    (core/secure_agg.py) -- the paper's technique as a framework feature:
    per-host gradient privacy against T colluders + straggler tolerance.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core import meshutil, secure_agg
from ..data import pipeline
from ..models import model_zoo as MZ
from ..models.config import ModelConfig
from ..optim import optimizers
from ..sharding import partition
from . import checkpoint as ckpt_lib


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 128
    microbatch: int = 0
    loss_chunk: int = 0
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    secure_agg: Optional[secure_agg.SecureAggConfig] = None


def train(cfg: ModelConfig, tcfg: TrainConfig, mesh=None, callback=None):
    """Returns (params, metrics_history)."""
    bm = MZ.build(cfg, microbatch=tcfg.microbatch,
                  loss_chunk=tcfg.loss_chunk)
    opt = optimizers.make(cfg.optimizer)
    key = jax.random.PRNGKey(tcfg.seed)

    params = bm.init_params(key)
    opt_state = opt.init(params)
    start_step = 0
    ckpt = ckpt_lib.Checkpointer(tcfg.ckpt_dir) if tcfg.ckpt_dir else None
    if ckpt and ckpt.list_steps():
        (restored, _) = ckpt.restore(
            {"params": params, "opt": opt_state, "step": 0})
        params, opt_state = restored["params"], restored["opt"]
        start_step = int(restored["step"]) + 1
        print(f"restored checkpoint, resuming at step {start_step}")

    if mesh is not None:
        pshard = partition.param_shardings(cfg, mesh)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s), params, pshard)

    dcfg = pipeline.LmDataConfig(vocab=cfg.vocab, seq_len=tcfg.seq_len,
                                 global_batch=tcfg.global_batch,
                                 seed=tcfg.seed)

    def step_fn(params, opt_state, batch, step):
        return bm.train_step(params, opt_state, batch, step)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))
    history = []
    ctx = meshutil.set_mesh(mesh) if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, tcfg.steps):
            batch = pipeline.lm_batch(dcfg, step)
            t0 = time.perf_counter()
            params, opt_state, metrics = jit_step(
                params, opt_state, batch, jnp.asarray(step, jnp.int32))
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                loss = float(metrics["loss"])
                rec = {"step": step, "loss": loss,
                       "grad_norm": float(metrics["grad_norm"]),
                       "step_time_s": time.perf_counter() - t0}
                history.append(rec)
                print(f"step {step:5d} loss {loss:8.4f} "
                      f"gnorm {rec['grad_norm']:8.3f} "
                      f"dt {rec['step_time_s']:6.2f}s")
                if callback:
                    callback(rec)
                assert np.isfinite(loss), f"loss diverged at step {step}"
            if ckpt and (step % tcfg.ckpt_every == 0 or
                         step == tcfg.steps - 1):
                ckpt.save(step, {"params": params, "opt": opt_state,
                                 "step": step})
    if ckpt:
        ckpt.wait()
    return params, history


def train_secure(cfg: ModelConfig, tcfg: TrainConfig):
    """Beyond-paper path: N virtual DP hosts, each computes its local
    gradient; gradients are combined with COPML-coded secure aggregation
    (information-theoretic privacy of each host's contribution against T
    colluders + straggler tolerance N - (T+1)).
    """
    sa = tcfg.secure_agg
    assert sa is not None
    bm = MZ.build(cfg, loss_chunk=tcfg.loss_chunk)
    opt = optimizers.make(cfg.optimizer)
    key = jax.random.PRNGKey(tcfg.seed)
    params = bm.init_params(key)
    opt_state = opt.init(params)
    dcfg = pipeline.LmDataConfig(vocab=cfg.vocab, seq_len=tcfg.seq_len,
                                 global_batch=tcfg.global_batch,
                                 seed=tcfg.seed)
    per = tcfg.global_batch // sa.n_clients

    @jax.jit
    def local_grads(params, batch):
        mbs = jax.tree.map(
            lambda x: x.reshape((sa.n_clients, per) + x.shape[1:]), batch)
        losses, grads = jax.vmap(
            lambda mb: jax.value_and_grad(
                lambda p: bm.loss_fn(p, mb)[0])(params))(mbs)
        return losses, grads

    @jax.jit
    def apply(params, opt_state, grads, step):
        return opt.update(grads, opt_state, params, step)

    history = []
    for step in range(tcfg.steps):
        batch = pipeline.lm_batch(dcfg, step)
        losses, stacked = local_grads(params, batch)
        per_client = [jax.tree.map(lambda x: x[i], stacked)
                      for i in range(sa.n_clients)]
        agg = secure_agg.secure_aggregate(
            jax.random.fold_in(key, step), per_client, sa)
        params, opt_state, gnorm = apply(
            params, opt_state, agg, jnp.asarray(step, jnp.int32))
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            rec = {"step": step, "loss": float(jnp.mean(losses))}
            history.append(rec)
            print(f"[secure-agg] step {step:4d} loss {rec['loss']:.4f}")
    return params, history


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False
