"""Elastic scaling + straggler mitigation utilities.

Two mechanisms (DESIGN.md section 5):

1. Re-mesh on restart: a checkpoint saved on one mesh restores onto any
   other (checkpoint.py stores full logical arrays; device_put against the
   new mesh's shardings re-shards).  `replan_mesh` picks the closest valid
   (data, model) factorization for the surviving device count.

2. Coded straggler tolerance -- the paper's own recovery threshold, promoted
   to a framework feature: a COPML gradient round decodes from ANY
   R = (2r+1)(K+T-1)+1 of N coded contributions, and Shamir-shared secure
   aggregation needs only T+1 of N shares.  `straggler_budget` reports how
   many hosts a given config can lose per step at zero recovery cost
   (vs. checkpoint-restart which costs minutes)."""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import lagrange, meshutil


def replan_shape(n_devices: int, prefer_model: int = 16) -> tuple:
    """Pure factorization behind replan_mesh: largest (data, model) with
    model | prefer_model that divides n_devices.  Testable without devices
    (non-power-of-two counts fall through to the largest fitting divisor;
    odd counts end at model=1)."""
    model = prefer_model
    while model > 1 and (n_devices % model or model > n_devices):
        model //= 2
    return n_devices // model, model


def replan_mesh(n_devices: int, prefer_model: int = 16):
    """Largest (data, model) mesh with model | prefer_model that fits."""
    data, model = replan_shape(n_devices, prefer_model)
    return meshutil.make_mesh((data, model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class StragglerBudget:
    n: int
    recovery_threshold: int

    @property
    def tolerable(self) -> int:
        return self.n - self.recovery_threshold


def straggler_budget(n: int, k: int, t: int, r: int = 1) -> StragglerBudget:
    return StragglerBudget(n, lagrange.recovery_threshold(r, k, t))


def secure_agg_budget(n: int, t: int) -> StragglerBudget:
    """Shamir aggregation: any T+1 of N shares reconstruct."""
    return StragglerBudget(n, t + 1)


# ------------------------------------------------- fault-plan budget checks
#
# The budgets above become *enforced* here: api.fit(..., faults=plan) routes
# a FaultPlan's per-step availability counts through validate_budget BEFORE
# any engine compiles or runs, so an under-provisioned churn schedule is a
# named error, not a silently-wrong decode.


class FaultPlanViolation(ValueError):
    """A fault schedule drops below the protocol's recovery threshold.

    Raised by plan validation before any compute happens; the message names
    the first violating step, its availability, and the threshold."""


def plan_headroom(available_counts, threshold: int) -> np.ndarray:
    """Per-step headroom: available contributors minus the recovery
    threshold.  Negative entries are the steps a decode would fail."""
    return np.asarray(available_counts, np.int64) - int(threshold)


def validate_budget(available_counts, threshold: int,
                    what: str = "decode") -> np.ndarray:
    """Reject schedules that ever drop below `threshold` contributors.

    available_counts: per-step number of honest, on-time clients.
    Returns the per-step headroom array on success; raises
    FaultPlanViolation naming the first violating step otherwise."""
    head = plan_headroom(available_counts, threshold)
    bad = np.flatnonzero(head < 0)
    if bad.size:
        s = int(bad[0])
        raise FaultPlanViolation(
            f"fault plan leaves {int(head[s]) + threshold} available "
            f"clients at step {s}, below the {what} recovery threshold "
            f"{threshold} ({bad.size} violating step(s) total)")
    return head
