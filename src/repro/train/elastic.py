"""Elastic scaling + straggler mitigation utilities.

Two mechanisms (DESIGN.md section 5):

1. Re-mesh on restart: a checkpoint saved on one mesh restores onto any
   other (checkpoint.py stores full logical arrays; device_put against the
   new mesh's shardings re-shards).  `replan_mesh` picks the closest valid
   (data, model) factorization for the surviving device count.

2. Coded straggler tolerance -- the paper's own recovery threshold, promoted
   to a framework feature: a COPML gradient round decodes from ANY
   R = (2r+1)(K+T-1)+1 of N coded contributions, and Shamir-shared secure
   aggregation needs only T+1 of N shares.  `straggler_budget` reports how
   many hosts a given config can lose per step at zero recovery cost
   (vs. checkpoint-restart which costs minutes)."""

from __future__ import annotations

import dataclasses

from ..core import lagrange, meshutil


def replan_mesh(n_devices: int, prefer_model: int = 16):
    """Largest (data, model) mesh with model | prefer_model that fits."""
    model = prefer_model
    while model > 1 and (n_devices % model or model > n_devices):
        model //= 2
    data = n_devices // model
    return meshutil.make_mesh((data, model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class StragglerBudget:
    n: int
    recovery_threshold: int

    @property
    def tolerable(self) -> int:
        return self.n - self.recovery_threshold


def straggler_budget(n: int, k: int, t: int, r: int = 1) -> StragglerBudget:
    return StragglerBudget(n, lagrange.recovery_threshold(r, k, t))


def secure_agg_budget(n: int, t: int) -> StragglerBudget:
    """Shamir aggregation: any T+1 of N shares reconstruct."""
    return StragglerBudget(n, t + 1)
