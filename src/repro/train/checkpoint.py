"""Sharded checkpointing with async save and restore-time resharding.

Fault-tolerance contract (DESIGN.md section 5):
  * save(step): every leaf is written as a .npy inside a step directory,
    with a JSON manifest (tree structure, shapes, dtypes, step).  On a real
    multi-host pod each host writes only the shards it owns (addressable
    shards); here the single process owns everything.
  * async: the array->host transfer happens synchronously (cheap), the disk
    write runs on a background thread so the train loop keeps stepping.
  * restore(mesh): leaves are re-placed with jax.device_put against the
    *current* mesh's shardings -- restoring a 256-chip checkpoint onto a
    512-chip (or 8-chip) mesh is the elastic-scaling path.
  * integrity: manifest is written last (atomic rename); partial writes from
    a crash are invisible to restore(), which picks the newest COMPLETE step.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, *, blocking: bool = False):
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(l) for l in leaves]   # device -> host now
        self.wait()                                      # one in flight max
        self._thread = threading.Thread(
            target=self._write, args=(step, host_leaves, str(treedef)),
            daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, leaves, treedef_str: str):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step:010d}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "treedef": treedef_str, "leaves": []}
        for i, leaf in enumerate(leaves):
            out = leaf
            if str(leaf.dtype) == "bfloat16":   # np.save can't serialize it
                out = leaf.view(np.uint16)
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), out)
            manifest["leaves"].append(
                {"shape": list(leaf.shape), "dtype": str(leaf.dtype)})
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)                            # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore

    def list_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, name, _MANIFEST)):
                out.append(int(name[5:]))
        return sorted(out)

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of `tree_like`.

        shardings: optional matching pytree of Shardings -- leaves are
        device_put against them (elastic re-mesh)."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        step = steps[-1] if step is None else step
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, _MANIFEST)) as f:
            manifest = json.load(f)
        leaves, treedef = _flatten(tree_like)
        host = []
        for i in range(len(leaves)):
            arr = np.load(os.path.join(path, f"leaf_{i}.npy"))
            if manifest["leaves"][i]["dtype"] == "bfloat16":
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            host.append(arr)
        def cast(h, template):
            dt = getattr(template, "dtype", None)
            if dt is None:                     # plain python scalar leaf
                return type(template)(h)
            return jax.device_put(h.astype(dt))

        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: x is None)
            placed = [jax.device_put(np.asarray(cast(h, l)), s)
                      if s is not None else cast(h, l)
                      for h, l, s in zip(host, leaves, sh_leaves)]
        else:
            placed = [cast(h, l) for h, l in zip(host, leaves)]
        return jax.tree_util.tree_unflatten(treedef, placed), step
