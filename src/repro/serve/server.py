"""SecureServer: micro-batched scoring against a secret-shared model.

Ties the two halves of the serving subsystem together: a CodedModel
(serve/coded.py -- the encode-once share artifact) and a MicroBatchQueue
(serve/queue.py -- the batching window).  Three engine kinds:

  eager    the op-by-op path: every window dispatches the field GEMM +
           reconstruct as individual XLA calls.  Ground truth.
  jit      ONE jitted scoring function; the queue's zero-padding keeps
           every window on the same (batch_size, d) shape, so steady-
           state serving is a single compiled dispatch per window.
  sharded  the jitted scorer with the client axis physically split over
           a 1-D ("clients",) mesh (serve/coded.sharded_scorer).

All three are bit-exact to each other and to the quantized reference
scorer -- the engine axis changes HOW a window executes, never what is
computed (the same contract the training engines keep).

The model stays secret-shared for the server's whole lifetime; the only
declassification is `coded.open_logits` on per-query scores, inside the
scoring function.  Predictions follow the workload's objective: argmax
for matrix models, sign for binary logistic, raw scores for regression.
"""

from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from ..core import quantize
from . import coded
from .queue import MicroBatchQueue

#: engine kinds a SecureServer can execute (api.serving validates the
#: spec; proc:N serving is future work -- the per-client share layout of
#: CodedModel.w_stack already matches the runtime's one-row-per-process
#: convention, so the interface does not preclude it)
SERVE_KINDS = ("eager", "jit", "sharded")


@dataclasses.dataclass
class SecureServer:
    """A live serving endpoint over one encoded model.

    Construct via `api.serve(workload, result, engine)`; the fields are
    the run specification plus the encode-once artifact.  `stats` is
    cumulative across serve() calls: queries / batches / padded rows /
    serve_s wall seconds / queries_per_s, plus the one-time encode_s."""
    workload: str             # workload name the model was trained on
    protocol: str             # protocol that produced the TrainResult
    engine: str               # engine label ("jit", "sharded:4", ...)
    kind: str                 # engine kind: eager | jit | sharded
    batch_size: int           # micro-batch window size
    window_ms: float          # micro-batch window in milliseconds
    model: coded.CodedModel   # the encode-once share artifact
    objective: object         # the workload's SecureObjective
    mesh: object | None = None          # 1-D client mesh (sharded only)
    stats: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in SERVE_KINDS:
            raise ValueError(
                f"unknown serve kind {self.kind!r}; expected one of "
                f"{SERVE_KINDS}")
        if self.kind == "sharded" and self.mesh is None:
            raise ValueError("sharded serving needs a mesh")
        self.stats.update({"queries": 0, "batches": 0, "padded": 0,
                           "serve_s": 0.0, "queries_per_s": 0.0,
                           "encode_s": self.model.encode_s})
        self._score = self._build_scorer()

    # ------------------------------------------------------------ scoring

    def _build_scorer(self):
        """fn(queries float (B, d)) -> Opened field logits (B, C')."""
        if self.kind == "sharded":
            return coded.sharded_scorer(self.model, self.mesh)
        model = self.model

        def fn(queries):
            xq = coded.quantize_queries(model, queries)
            return coded.open_logits(coded.score_shares(model, xq), model)

        if self.kind == "jit":
            import jax
            return jax.jit(fn)
        return fn

    def score_field(self, queries) -> np.ndarray:
        """Exact field-domain logits (B, C') int32 at scale lx + lw --
        the value tests compare bit-for-bit against
        `coded.reference_scores` of the opened model."""
        zf = self._score(jnp.asarray(queries, jnp.float32))
        return np.asarray(zf)

    def logits(self, queries) -> np.ndarray:
        """Dequantized float logits (B, C')."""
        zf = self._score(jnp.asarray(queries, jnp.float32))
        return np.asarray(quantize.dequantize(zf, self.model.lz))

    def predict(self, queries) -> np.ndarray:
        """Per-query decisions on an un-queued batch (see _decide)."""
        return self._decide(self.logits(queries))

    def _decide(self, logits: np.ndarray) -> np.ndarray:
        """(B, C') float logits -> per-query outputs: argmax class index
        for matrix models, {0,1} sign decision for binary logistic, raw
        scores for regression."""
        if self.model.out_shape:
            return np.argmax(logits, axis=1)
        if getattr(self.objective, "dataset_kind", "binary") == "regression":
            return logits[:, 0]
        return (logits[:, 0] > 0).astype(np.int32)

    # ------------------------------------------------------- the serve loop

    def serve(self, queries, clock=None) -> tuple:
        """Stream `queries` (Q, d) through the micro-batch window.

        Returns (predictions (Q,) in submission order, stats).  Windows
        flush when full or when `window_ms` expires between submissions
        (the injectable `clock` makes the expiry testable); the stream's
        tail flushes unconditionally, zero-padded to batch_size."""
        q = MicroBatchQueue(self.batch_size, self.window_ms,
                            clock=clock if clock is not None
                            else time.monotonic)
        rows = np.asarray(queries, np.float32)
        assert rows.ndim == 2 and rows.shape[1] == self.model.d, (
            rows.shape, self.model.d)
        out: dict = {}
        t0 = time.perf_counter()
        for row in rows:
            q.submit(row)
            if q.ready():
                self._flush(q, out)
        while len(q):                       # end of stream: drain the tail
            self._flush(q, out)
        elapsed = time.perf_counter() - t0
        self.stats["serve_s"] += elapsed
        self.stats["queries_per_s"] = (
            self.stats["queries"] / max(self.stats["serve_s"], 1e-9))
        preds = np.asarray([out[i] for i in range(len(rows))])
        return preds, dict(self.stats)

    def _flush(self, q: MicroBatchQueue, out: dict) -> None:
        tickets, batch, n_valid = q.drain()
        zf = self._score(jnp.asarray(batch))
        logits = np.asarray(quantize.dequantize(zf, self.model.lz))
        decisions = self._decide(logits[:n_valid])
        for ticket, value in zip(tickets, decisions):
            out[ticket] = value
        self.stats["queries"] += n_valid
        self.stats["batches"] += 1
        self.stats["padded"] += len(batch) - n_valid

    def summary(self) -> str:
        s = self.stats
        return (f"{self.workload} x {self.protocol} x {self.engine}: "
                f"{s['queries']} queries in {s['batches']} batches "
                f"({s['padded']} padded rows), "
                f"{s['queries_per_s']:.0f} q/s, "
                f"encode {s['encode_s'] * 1e3:.1f}ms")
