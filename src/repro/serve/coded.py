"""Encode-once coded inference on a secret-shared model.

The serving-side counterpart of the protocol's encode-once/compute-many
training structure: the trained model is re-shared ONCE into per-client
Shamir shares packed for the limb-GEMM kernels, and every incoming query
batch is scored against those shares without ever opening the model.

Why this is secure *and* exact: Shamir sharing is mod-p linear, so each
client's LOCAL field matmul  xq @ w_share_i  is itself a share of the
score polynomial evaluated at that client's point, and reconstructing
the per-query logits from any T+1 of them yields exactly  xq @ wq mod p
-- bit-identical to the quantized reference scorer `reference_scores`
(tests/test_serve.py asserts equality, not closeness).  The model never
exists in the clear anywhere on the serving path; only per-query logits
pass through the sanctioned `open_logits` sink below (registered as an
`open` effect in analysis/registry.py, annotated `-> Opened`).

Encode path:

* a COPML TrainResult carries the protocol-native final state
  (CopmlState.w_shares, shares at the protocol's serving lambdas):
  `encode_model` degree-refreshes them with `shamir.reshare` at those
  SAME points -- the model secret is never reconstructed in between;
* results without share state (float baselines, secure_agg) fall back to
  quantize + fresh `shamir.share` of the opened weights -- still served
  from shares, but the encode step sees the clear model (flagged in the
  CodedModel as `from_shares=False`).

The packed `w_cols` layout (d, N*C') turns per-batch scoring for ALL N
clients and C' model columns into ONE field GEMM (kernels.ops.modmatmul)
-- that reshape is the "encode once" amortization the serving benchmark
measures.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from ..core import field, meshutil, quantize, shamir
from ..core.labels import Opened, Public, Share
from ..kernels import ops as kernel_ops


def serving_points(cfg) -> tuple:
    """The share evaluation points of a CopmlState's w_shares: the
    protocol's serving lambdas (core/protocol.Copml.__init__), disjoint
    from the K+T encoding betas and the N coding alphas."""
    n, k, t = cfg.n_clients, cfg.k, cfg.t
    return tuple(range(k + t + 1 + n, k + t + 1 + 2 * n))


@dataclasses.dataclass
class CodedModel:
    """The encode-once serving artifact: per-client model shares, packed.

    w_stack is the canonical (N, d, C') share stack (C' = 1 for vector
    models); w_cols is the SAME shares reshaped to (d, N*C') so one
    limb-GEMM scores a whole query batch for every client and class at
    once.  Both are secret -- only `open_logits` may leave the share
    domain."""
    w_stack: Share            # (N, d, C') per-client shares of wq
    w_cols: Share             # (d, N*C') the packed scoring layout
    n: int                    # clients (shareholders)
    t: int                    # privacy threshold: any T+1 shares open
    points: tuple             # share evaluation points (len N)
    d: int                    # feature dimension
    out_shape: tuple          # () vector model | (C,) matrix model
    lx: int                   # query quantization scale
    lw: int                   # model quantization scale
    from_shares: bool         # True: re-shared protocol state, model
    #                           never opened on the encode path
    encode_s: float           # wall seconds of the one-time encode

    @property
    def n_cols(self) -> int:
        """C': model columns served per query (1 for vector models)."""
        return self.out_shape[0] if self.out_shape else 1

    @property
    def lz(self) -> int:
        """Scale of the opened field logits: lx + lw."""
        return self.lx + self.lw


def encode_model(key, result, cfg, objective) -> CodedModel:
    """One-time model encode: TrainResult -> CodedModel.

    Prefers the protocol-native share state (reshare at the protocol's
    serving lambdas -- fresh randomness, same secret, model never
    opened); falls back to quantize+share of the opened weights."""
    n, t = cfg.n_clients, cfg.t
    d = int(jnp.asarray(result.weights).shape[0])
    out_shape = tuple(objective.out_shape)
    cols = out_shape[0] if out_shape else 1

    state = getattr(result, "state", None)
    w_shares = getattr(state, "w_shares", None)
    t0 = time.perf_counter()
    if w_shares is not None:
        points = serving_points(cfg)
        shares = shamir.reshare(key, w_shares, t, n, points)
        from_shares = True
    else:
        points = shamir.default_eval_points(n)
        wq = quantize.quantize(jnp.asarray(result.weights), cfg.lw)
        shares = shamir.share(key, wq, t, n, points)
        from_shares = False
    w_stack = shares.reshape(n, d, cols)
    w_cols = jnp.moveaxis(w_stack, 0, 1).reshape(d, n * cols)
    jax.block_until_ready(w_cols)
    encode_s = time.perf_counter() - t0
    return CodedModel(w_stack=w_stack, w_cols=w_cols, n=n, t=t,
                      points=points, d=d, out_shape=out_shape,
                      lx=cfg.lx, lw=cfg.lw, from_shares=from_shares,
                      encode_s=encode_s)


def quantize_queries(model: CodedModel, queries) -> Public:
    """Float query batch (B, d) -> field domain at the data scale lx."""
    x = jnp.asarray(queries, jnp.float32)
    assert x.ndim == 2 and x.shape[1] == model.d, (x.shape, model.d)
    return quantize.quantize(x, model.lx)


def score_shares(model: CodedModel, xq: Public) -> Share:
    """Per-client share of the query logits: ONE packed limb-GEMM.

    xq: (B, d) quantized queries.  Returns (N, B, C') -- client i's rows
    are Shamir shares (at points[i]) of the logit matrix xq @ wq, because
    sharing commutes with the mod-p linear map xq @ (.)."""
    bsz = xq.shape[0]
    z = kernel_ops.modmatmul(xq, model.w_cols)          # (B, N*C')
    return jnp.moveaxis(z.reshape(bsz, model.n, model.n_cols), 1, 0)


def open_logits(z_shares: Share, model: CodedModel) -> Opened:
    """THE serving declassify sink: reconstruct per-query logits only.

    Any T+1 client scores interpolate to the exact field logits
    xq @ wq mod p, shape (B, C').  Nothing model-shaped is ever opened
    here -- (B, C') is public output, the model stays (N, d, C') shares.
    Registered as an `open` effect in analysis/registry.py."""
    return shamir.reconstruct(z_shares, model.t, model.points)


def score_open(model: CodedModel, queries) -> tuple:
    """Quantize -> share-score -> open: (field logits, float logits).

    The eager reference path: field logits are (B, C') int32 at scale
    lx + lw (bit-exact vs `reference_scores`); float logits are their
    dequantization."""
    xq = quantize_queries(model, queries)
    zf = open_logits(score_shares(model, xq), model)
    return zf, quantize.dequantize(zf, model.lz)


def sharded_scorer(model: CodedModel, mesh):
    """A jitted scoring fn with the client axis SPLIT over a 1-D
    ("clients",) mesh: each shard scores its own clients' model shares
    locally (the per-client compute really is per-device), the opened
    logits are the only cross-shard product (all_gather + reconstruct,
    replicated).  Returns fn(queries float (B, d)) -> Opened field
    logits (B, C'), bit-identical to the single-device path."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    assert mesh.axis_names == (meshutil.CLIENT_AXIS,), mesh.axis_names
    ndev = mesh.devices.size
    n, d, cols = model.n, model.d, model.n_cols
    n_loc = -(-n // ndev)
    n_pad = n_loc * ndev
    w_stack = model.w_stack
    if n_pad > n:       # zero rows: excluded from reconstruction below
        w_stack = jnp.concatenate(
            [w_stack, jnp.zeros((n_pad - n, d, cols), jnp.int32)], axis=0)

    def score(w_loc: Share, xq: Public) -> Opened:
        n_here = w_loc.shape[0]
        w_c = jnp.moveaxis(w_loc, 0, 1).reshape(d, n_here * cols)
        z = kernel_ops.modmatmul(xq, w_c)               # (B, n_loc*C')
        z = jnp.moveaxis(z.reshape(-1, n_here, cols), 1, 0)
        z_all = meshutil.all_gather_clients(z)[:n]      # OPEN step
        return shamir.reconstruct(z_all, model.t, model.points)

    cl = P(meshutil.CLIENT_AXIS)
    sm = shard_map(score, mesh, in_specs=(cl, P()), out_specs=P(),
                   check_rep=False)

    def fn(queries):
        xq = quantize_queries(model, queries)
        return sm(w_stack, xq)

    return jax.jit(fn)


def reference_scores(weights, queries, cfg) -> Public:
    """The quantized reference scorer the secure path must match BIT FOR
    BIT: quantize the OPENED model and the queries exactly as the secure
    path does, one clear field matmul.  (d,) models score as one column;
    returns (B, C') int32 field logits at scale lx + lw."""
    w = jnp.asarray(weights, jnp.float32)
    wq = quantize.quantize(w.reshape(w.shape[0], -1), cfg.lw)
    xq = quantize.quantize(jnp.asarray(queries, jnp.float32), cfg.lx)
    return field.matmul(xq, wq)
