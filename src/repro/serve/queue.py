"""Micro-batch accumulation for the secure serving path.

Queries arrive one at a time; field GEMMs want batches.  The queue
accumulates up to `batch_size` queries or `window_ms` milliseconds --
whichever comes first -- then drains ONE zero-padded (batch_size, d)
batch, so the server scores every window through a single jitted
function per shape (no per-batch recompiles for ragged tails).

Secrecy note: queries and predictions are the *client's* data on the
serving path -- the queue never touches model shares, so it carries no
field/share invariants.  Determinism note: the clock is injectable
(`clock=` returns seconds, default time.monotonic) so the window policy
is testable without sleeping (tests/test_serve.py drives a fake clock).
"""

from __future__ import annotations

import time

import numpy as np


class MicroBatchQueue:
    """Accumulate queries; flush on batch-full or window-expired.

    submit() returns a monotonically increasing ticket; drain() returns
    the tickets of the drained window in submission order, so callers
    can re-associate predictions with queries (order preservation is a
    property test, not a convention).
    """

    def __init__(self, batch_size: int, window_ms: float,
                 clock=time.monotonic):
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        if window_ms < 0:
            raise ValueError(f"window_ms must be >= 0, got {window_ms}")
        self.batch_size = int(batch_size)
        self.window_ms = float(window_ms)
        self.clock = clock
        self._rows: list = []        # (ticket, (d,) float32 row)
        self._next_ticket = 0
        self._window_start: float | None = None

    def __len__(self) -> int:
        return len(self._rows)

    def submit(self, query) -> int:
        """Enqueue one (d,) query; returns its ticket."""
        row = np.asarray(query, np.float32)
        if row.ndim != 1:
            raise ValueError(f"expected a (d,) query row, got {row.shape}")
        if self._rows and row.shape != self._rows[0][1].shape:
            raise ValueError(
                f"query dim {row.shape} != pending {self._rows[0][1].shape}")
        if not self._rows:
            self._window_start = self.clock()
        ticket = self._next_ticket
        self._next_ticket += 1
        self._rows.append((ticket, row))
        return ticket

    def ready(self, now: float | None = None) -> bool:
        """True when a window should flush: batch full, or the oldest
        pending query has waited >= window_ms."""
        if not self._rows:
            return False
        if len(self._rows) >= self.batch_size:
            return True
        now = self.clock() if now is None else now
        return (now - self._window_start) * 1e3 >= self.window_ms

    def drain(self) -> tuple:
        """Pop one window: (tickets, batch, n_valid).

        batch is ALWAYS (batch_size, d) float32 -- ragged tails are
        zero-padded so every window hits the same compiled scorer;
        n_valid says how many leading rows are real queries."""
        if not self._rows:
            raise ValueError("drain() on an empty queue")
        take = self._rows[: self.batch_size]
        self._rows = self._rows[self.batch_size:]
        self._window_start = self.clock() if self._rows else None
        tickets = tuple(tk for tk, _ in take)
        d = take[0][1].shape[0]
        batch = np.zeros((self.batch_size, d), np.float32)
        for i, (_, row) in enumerate(take):
            batch[i] = row
        return tickets, batch, len(take)
