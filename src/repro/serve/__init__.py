"""repro.serve -- the secure serving subsystem.

Encode-once coded inference on a secret-shared model: `coded` holds the
share-domain math (encode, packed scoring GEMM, the `open_logits`
declassify sink, the quantized reference scorer), `queue` the
micro-batch window, `server` the SecureServer endpoint.  The front door
is `repro.api.serve(workload, result, engine)`.
"""

from .coded import CodedModel, encode_model, open_logits, reference_scores
from .queue import MicroBatchQueue
from .server import SERVE_KINDS, SecureServer

__all__ = ["CodedModel", "MicroBatchQueue", "SERVE_KINDS", "SecureServer",
           "encode_model", "open_logits", "reference_scores"]
