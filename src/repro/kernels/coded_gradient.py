"""Pallas TPU kernel: FUSED coded gradient  f = X~^T ghat(X~ w~)  over F_p.

This is COPML's hot loop (paper Eq. 7, the first column of Table I).  A naive
implementation reads X~ twice (once for z = X~ w~, once for X~^T g).  Fusing
both passes over a single VMEM-resident row-block of X~ halves HBM traffic --
the op is memory-bound (arithmetic intensity ~ O(1) per X~ element for the
matvec pair), so this is a ~2x win on the memory roofline term.

Grid: one dimension over row blocks of X~; the (d,) output accumulator lives
in VMEM and is revisited by every grid step.  Field arithmetic follows
modmatmul.py: 7-bit limbs -> exact f32 MXU products -> int32 recombination.

`coded_gradient_batched` adds a leading client dimension: the COPML hot loop
computes f for ALL N clients every iteration (each with its own coded slice
X~_i and coded model w~_i), so a (N, m/bm) grid runs the whole round as ONE
pallas_call -- one dispatch, one pipeline, w~_i resident in VMEM across a
client's row blocks -- instead of N single-client launches under an outer
vmap.

`coded_gradient_matrix` is the class-batched form for MATRIX models
(multi-class one-vs-rest): w~_i is (d, C), so both passes are real GEMMs
(C columns in the MXU free dimension) on the same (N, m/bm) grid -- one
launch computing X~^T ghat(X~ W) for every client and every class, instead
of C matvec dispatches per client.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import field

DEFAULT_BM = 256     # rows of X~ per block (contraction width for X^T g)
DEFAULT_DC = 512     # d-chunk width (contraction width for X w)


def _limb(x, i):
    return jnp.bitwise_and(
        jax.lax.shift_right_logical(x, 7 * i), 0x7F).astype(jnp.float32)


def _limb_dot_mod(a, b, contract_a: int, contract_b: int):
    """Field 'matmul' of int32 blocks a, b contracting the given dims.

    Contraction length must be <= 1024 (exact f32).  Returns int32 mod p.
    The 16 limb-pair MXU partials are grouped by weight class s = i+j in
    int32 and recombined with ONE Barrett reduce (field.recombine_limb_
    groups) instead of the historical per-term fold26 + modular multiply.
    """
    groups = [None] * 7
    dn = (((contract_a,), (contract_b,)), ((), ()))
    for i in range(4):
        ai = _limb(a, i)
        for j in range(4):
            bj = _limb(b, j)
            s = jax.lax.dot_general(ai, bj, dn,
                                    preferred_element_type=jnp.float32)
            term = s.astype(jnp.int32)
            g = groups[i + j]
            groups[i + j] = term if g is None else g + term
    return field.recombine_limb_groups(groups)


def _fused_block(x, w, c_ref, o_ref, pre: tuple, *, degree: int, dc: int):
    """Shared body: one (bm, d) row block of one client's coded slice.

    `pre` indexes into o_ref ahead of the d-slice: () for the single-client
    kernel's (d,) output block, (0,) for the batched kernel's (1, d) block.
    """
    bm, d = x.shape

    # pass 1: z = (X_blk @ w) mod p, chunked over d for f32 exactness
    z = jnp.zeros((bm,), jnp.int32)
    for c in range(0, d, dc):
        xc = x[:, c:c + dc]
        wc = w[c:c + dc]
        z = field.add(z, _limb_dot_mod(xc, wc[:, None], 1, 0)[:, 0])

    # ghat(z): unrolled Horner (VPU)
    g = jnp.broadcast_to(c_ref[degree], z.shape)
    for t in range(degree - 1, -1, -1):
        g = field.add(field.mul(g, z), jnp.broadcast_to(c_ref[t], z.shape))

    # pass 2: acc += X_blk^T g  (contraction over bm <= 1024)
    for c in range(0, d, dc):
        xc = x[:, c:c + dc]
        upd = _limb_dot_mod(xc, g[:, None], 0, 0)[:, 0]   # (dc,)
        sl = pre + (slice(c, c + dc),)
        o_ref[sl] = field.add(o_ref[sl], upd)


def _kernel(x_ref, w_ref, c_ref, o_ref, *, degree: int, dc: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _fused_block(x_ref[...], w_ref[...], c_ref, o_ref, (),
                 degree=degree, dc=dc)


def _kernel_batched(x_ref, w_ref, c_ref, o_ref, *, degree: int, dc: int):
    i = pl.program_id(1)                # row-block index (innermost)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _fused_block(x_ref[0], w_ref[0], c_ref, o_ref, (0,),
                 degree=degree, dc=dc)


@functools.partial(jax.jit,
                   static_argnames=("bm", "dc", "interpret"))
def coded_gradient(x, w, coeffs, *, bm: int = DEFAULT_BM,
                   dc: int = DEFAULT_DC, interpret: bool = True):
    """f = (x^T ghat(x @ w)) mod p.

    x: (m, d) int32 field; w: (d,); coeffs: (r+1,).  m % bm == 0,
    d % dc == 0 (ops.py pads); bm, dc <= 1024.
    """
    m, d = x.shape
    assert m % bm == 0 and d % dc == 0, (x.shape, bm, dc)
    assert bm <= 1024 and dc <= 1024
    degree = coeffs.shape[0] - 1
    return pl.pallas_call(
        functools.partial(_kernel, degree=degree, dc=dc),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((coeffs.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((d,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.int32),
        interpret=interpret,
    )(x, w, coeffs)


def _fused_block_matrix(x, w, c_ref, o_ref, *, degree: int, dc: int):
    """One (bm, d) row block of one client's coded slice against a (d, C)
    matrix model: the class-batched twin of _fused_block.  Both passes are
    (.., dc) x (dc, C)-ish GEMMs with C in the free dimension; the
    contraction widths (dc for pass 1, bm for pass 2) keep the f32 limb
    products exact as in the vector kernel."""
    bm, d = x.shape
    c = w.shape[1]

    # pass 1: Z = (X_blk @ W) mod p, chunked over d for f32 exactness
    z = jnp.zeros((bm, c), jnp.int32)
    for s in range(0, d, dc):
        z = field.add(z, _limb_dot_mod(x[:, s:s + dc], w[s:s + dc, :], 1, 0))

    # ghat(Z): unrolled Horner (VPU), elementwise over the (bm, C) block
    g = jnp.broadcast_to(c_ref[degree], z.shape)
    for t in range(degree - 1, -1, -1):
        g = field.add(field.mul(g, z), jnp.broadcast_to(c_ref[t], z.shape))

    # pass 2: acc += X_blk^T G  (contraction over bm <= 1024)
    for s in range(0, d, dc):
        upd = _limb_dot_mod(x[:, s:s + dc], g, 0, 0)          # (dc, C)
        o_ref[0, s:s + dc, :] = field.add(o_ref[0, s:s + dc, :], upd)


def _kernel_matrix(x_ref, w_ref, c_ref, o_ref, *, degree: int, dc: int):
    i = pl.program_id(1)                # row-block index (innermost)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _fused_block_matrix(x_ref[0], w_ref[0], c_ref, o_ref,
                        degree=degree, dc=dc)


@functools.partial(jax.jit,
                   static_argnames=("bm", "dc", "interpret"))
def coded_gradient_matrix(x, w, coeffs, *, bm: int = DEFAULT_BM,
                          dc: int = DEFAULT_DC, interpret: bool = True):
    """f[n] = (x[n]^T ghat(x[n] @ w[n])) mod p for (N, d, C) matrix models.

    x: (N, m, d) int32 field; w: (N, d, C); coeffs: (r+1,) shared across
    clients and classes.  m % bm == 0, d % dc == 0 (ops.py pads); the class
    width C rides in the GEMM free dimension (C <= 1024 to keep the output
    block VMEM-resident).  Grid (N, m/bm), row blocks innermost, exactly as
    the vector kernel.
    """
    nb, m, d = x.shape
    assert w.shape[:2] == (nb, d), (x.shape, w.shape)
    c = w.shape[2]
    assert m % bm == 0 and d % dc == 0, (x.shape, bm, dc)
    assert bm <= 1024 and dc <= 1024 and c <= 1024
    degree = coeffs.shape[0] - 1
    return pl.pallas_call(
        functools.partial(_kernel_matrix, degree=degree, dc=dc),
        grid=(nb, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, d, c), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((coeffs.shape[0],), lambda n, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d, c), lambda n, i: (n, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, d, c), jnp.int32),
        interpret=interpret,
    )(x, w, coeffs)


@functools.partial(jax.jit,
                   static_argnames=("bm", "dc", "interpret"))
def coded_gradient_batched(x, w, coeffs, *, bm: int = DEFAULT_BM,
                           dc: int = DEFAULT_DC, interpret: bool = True):
    """f[n] = (x[n]^T ghat(x[n] @ w[n])) mod p for all N clients at once.

    x: (N, m, d) int32 field; w: (N, d); coeffs: (r+1,) shared across
    clients (same ghat everywhere).  m % bm == 0, d % dc == 0 (ops.py pads).
    Grid (N, m/bm): the row-block dimension is innermost so client n's
    output block and w~_n stay VMEM-resident across its whole slice.
    """
    nb, m, d = x.shape
    assert w.shape == (nb, d), (x.shape, w.shape)
    assert m % bm == 0 and d % dc == 0, (x.shape, bm, dc)
    assert bm <= 1024 and dc <= 1024
    degree = coeffs.shape[0] - 1
    return pl.pallas_call(
        functools.partial(_kernel_batched, degree=degree, dc=dc),
        grid=(nb, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, d), lambda n, i: (n, 0)),
            pl.BlockSpec((coeffs.shape[0],), lambda n, i: (0,)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda n, i: (n, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, d), jnp.int32),
        interpret=interpret,
    )(x, w, coeffs)
