"""Pallas TPU kernel: finite-field matmul over F_p, p = 2^26 - 5.

TPU-native adaptation of the paper's 64-bit lazy-reduction trick (App. A):
operands are decomposed into four 7-bit limbs; the 16 limb-pair partial
matmuls run EXACTLY on the MXU in f32 (products < 2^14, accumulated over a
<= 1024-wide K block stays < 2^24, f32's exact-integer range); recombination
back to F_p is pure int32 (13-bit-limb modular multiply, every intermediate
< 2^31).  No 64-bit types anywhere -- this kernel lowers to TPU as-is.

Grid: (M/bm, N/bn, K/bk) with K innermost ("arbitrary" semantics); the
output block is revisited across the K dimension and accumulated in VMEM.

`modmatmul_batched` prepends a batch dimension -- grid (B, M/bm, N/bn, K/bk)
-- so B independent field matmuls (e.g. one per COPML client) run as a single
pallas_call instead of B launches under an outer vmap.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import field

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 512  # <= 1024 for exact f32 limb accumulation


def _limb(x, i):
    return jnp.bitwise_and(
        jax.lax.shift_right_logical(x, 7 * i), 0x7F).astype(jnp.float32)


def _limb_matmul_mod(a_blk, b_blk):
    """Field matmul of one (bm, bk) x (bk, bn) block; all int32/f32.

    16 MXU matmuls + int32 modular recombination.  Requires bk <= 1024.
    Limb-pair partials sharing a weight class s = i+j are summed in int32
    and the static 2^(7s) weights applied lazily, so the whole block costs
    ONE Barrett reduce (field.recombine_limb_groups) instead of 16
    fold26 + modular-multiply chains.
    """
    groups = [None] * 7
    for i in range(4):
        ai = _limb(a_blk, i)
        for j in range(4):
            bj = _limb(b_blk, j)
            s = jnp.dot(ai, bj, preferred_element_type=jnp.float32)
            term = s.astype(jnp.int32)
            g = groups[i + j]
            groups[i + j] = term if g is None else g + term
    return field.recombine_limb_groups(groups)


def _kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] = field.add(o_ref[...], _limb_matmul_mod(a_ref[...], b_ref[...]))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def modmatmul(a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
              bk: int = DEFAULT_BK, interpret: bool = True):
    """(a @ b) mod p.  a: (M, K), b: (K, N) int32 field elements.

    Shapes must be multiples of the block sizes (ops.py pads).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape)
    assert bk <= 1024, "bk > 1024 breaks exact f32 limb accumulation"
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(a, b)


def _kernel_batched(a_ref, b_ref, o_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[0] = field.add(o_ref[0], _limb_matmul_mod(a_ref[0], b_ref[0]))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def modmatmul_batched(a, b, *, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                      bk: int = DEFAULT_BK, interpret: bool = True):
    """(a[i] @ b[i]) mod p for all i.  a: (B, M, K), b: (B, K, N) int32.

    M/N/K must be multiples of the block sizes (ops.py pads).
    """
    bsz, m, k = a.shape
    bsz2, k2, n = b.shape
    assert bsz == bsz2 and k == k2, (a.shape, b.shape)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (a.shape, b.shape)
    assert bk <= 1024, "bk > 1024 breaks exact f32 limb accumulation"
    grid = (bsz, m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _kernel_batched,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda bi, i, j, kk: (bi, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda bi, i, j, kk: (bi, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda bi, i, j, kk: (bi, i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m, n), jnp.int32),
        interpret=interpret,
    )(a, b)
