"""Offline (bm, dc) block autotuner for the gradient-family Pallas kernels.

Sweeps candidate block shapes for a given (m, d, C) bucket by timing the
class-batched coded-gradient kernel (the megakernel's inner loop -- block
choice affects both identically) and caches the winner in a JSON table
(`kernels/blocks.json` by default) consulted by `ops.pick_blocks` at
dispatch time.  Selection is a pure performance knob: every candidate is
bit-exact (partials are fully reduced mod p before accumulation), so the
table never needs revalidation, only re-timing on new hardware.

CLI:

    PYTHONPATH=src python -m repro.kernels.tune \
        --shape 390,24,10 --shape 512,512,1 --reps 3 \
        --out src/repro/kernels/blocks.json

Runtime override without touching the table: REPRO_PALLAS_BLOCKS="bm,dc".
"""

from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from ..core import field
from . import coded_gradient as _cg
from . import ops

BM_CANDIDATES = (32, 64, 128, 256, 512)
DC_CANDIDATES = (32, 64, 128, 256, 512)


def _candidates(m: int, d: int, c: int):
    """Blocks worth timing for this bucket: no block larger than the padded
    shape's power-of-2 ceiling (bigger only adds padding waste)."""
    mb, db = ops._bucket(m), ops._bucket(d)
    bms = sorted({min(bm, mb) for bm in BM_CANDIDATES})
    dcs = sorted({min(dc, db) for dc in DC_CANDIDATES})
    return [(bm, dc) for bm in bms for dc in dcs]


def _time_blocks(x, w, coeffs, bm: int, dc: int, reps: int) -> float:
    def call():
        out = ops.coded_gradient_matrix(x, w, coeffs, bm=bm, dc=dc,
                                        force_pallas=True)
        out.block_until_ready()
        return out

    call()                                     # compile/warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        call()
        best = min(best, time.perf_counter() - t0)
    return best


def tune_shape(m: int, d: int, c: int, *, n_clients: int = 4,
               reps: int = 3, verbose: bool = False) -> dict:
    """Time every candidate for one (m, d, C) bucket; return the winner."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, field.P, size=(n_clients, m, d),
                                 dtype=np.int64).astype(np.int32))
    w = jnp.asarray(rng.integers(0, field.P, size=(n_clients, d, c),
                                 dtype=np.int64).astype(np.int32))
    coeffs = jnp.asarray(rng.integers(0, field.P, size=(3,),
                                      dtype=np.int64).astype(np.int32))
    best = None
    for bm, dc in _candidates(m, d, c):
        dt = _time_blocks(x, w, coeffs, bm, dc, reps)
        if verbose:
            print(f"  bm={bm:4d} dc={dc:4d}  {dt * 1e3:8.2f} ms")
        if best is None or dt < best["us"]:
            best = {"bm": bm, "dc": dc, "us": dt}
    return {"bm": best["bm"], "dc": best["dc"],
            "us": round(best["us"] * 1e6, 1)}


def update_table(path: str, shapes, *, reps: int = 3,
                 verbose: bool = False) -> dict:
    """Tune each (m, d, c) shape and merge winners into the JSON table."""
    try:
        with open(path) as fh:
            table = json.load(fh)
    except (OSError, ValueError):
        table = {}
    for m, d, c in shapes:
        key = ops.block_key(m, d, c)
        if verbose:
            print(f"{key}  (m={m}, d={d}, C={c})")
        table[key] = tune_shape(m, d, c, reps=reps, verbose=verbose)
    with open(path, "w") as fh:
        json.dump(table, fh, indent=2, sort_keys=True)
        fh.write("\n")
    return table


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--shape", action="append", default=[],
                    metavar="M,D,C", help="shape bucket to tune (repeatable)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--out", default=ops._BLOCKS_PATH)
    args = ap.parse_args(argv)
    shapes = [tuple(int(v) for v in s.split(",")) for s in args.shape]
    if not shapes:
        shapes = [(390, 24, 10), (512, 512, 1)]   # mnist10_like + GEMM-ish
    table = update_table(args.out, shapes, reps=args.reps, verbose=True)
    print(f"wrote {len(table)} entries -> {args.out}")


if __name__ == "__main__":
    main()
