"""Public jit'd wrappers for the Pallas kernels: padding, dispatch, fallback.

On this CPU container the kernels run in interpret mode (the kernel body
executes exactly as written); on TPU set REPRO_PALLAS_INTERPRET=0.  Small
shapes fall back to the pure-jnp reference (padding overhead would dominate).
"""

from __future__ import annotations

import json
import os

import jax.numpy as jnp

from . import coded_gradient as _cg
from . import field_poly as _fp
from . import fused_step as _fs
from . import modmatmul as _mm
from . import ref
from ..core.labels import Coded, Public

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
# interpret-mode kernels are slow on CPU; route big shapes only when asked
USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") != "0"

# ---------------------------------------------------------------------------
# (bm, dc) block selection for the gradient-family kernels.
#
# Priority: REPRO_PALLAS_BLOCKS="bm,dc" env override > the offline tuner's
# JSON table (kernels/blocks.json, written by `python -m repro.kernels.tune`)
# keyed by the power-of-2 (m, d, C) bucket > a shape-derived fallback.  Any
# choice is bit-exact (every partial is fully reduced mod p before
# accumulation, so chunking cannot change the canonical int32 result);
# selection only affects padding waste and VMEM residency.

_BLOCKS_PATH = os.path.join(os.path.dirname(__file__), "blocks.json")
_block_table_cache = None


def _block_table():
    global _block_table_cache
    if _block_table_cache is None:
        try:
            with open(_BLOCKS_PATH) as fh:
                _block_table_cache = json.load(fh)
        except (OSError, ValueError):
            _block_table_cache = {}
    return _block_table_cache


def _bucket(v: int) -> int:
    """Power-of-2 ceiling, floored at 8 (the smallest legal block)."""
    b = 8
    while b < v:
        b *= 2
    return b


def block_key(m: int, d: int, c: int = 1) -> str:
    return f"m{_bucket(m)}_d{_bucket(d)}_c{_bucket(c)}"


def pick_blocks(m: int, d: int, c: int = 1) -> tuple[int, int]:
    """(bm, dc) for an (m, d, C) gradient-family shape.

    The fallback derives minima from the ACTUAL shape including the class
    width: the matrix path's VMEM block holds (bm, d) of X~ plus the
    (dc, C) output slice, so dc is shrunk when C is wide instead of
    reusing the vector-path minimum (which padded ragged class-batched
    shapes pathologically -- see the (m=13, C=10) regression test).
    """
    env = os.environ.get("REPRO_PALLAS_BLOCKS", "")
    if env:
        bm_s, dc_s = env.split(",")
        return int(bm_s), int(dc_s)
    entry = _block_table().get(block_key(m, d, c))
    if entry:
        return int(entry["bm"]), int(entry["dc"])
    bm = min(_cg.DEFAULT_BM, _bucket(m))
    dc = min(_cg.DEFAULT_DC, _bucket(d))
    while c > 1 and dc * _bucket(c) > 16384 and dc > 8:
        dc //= 2
    return bm, dc


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def modmatmul(a, b, *, bm=None, bn=None, bk=None, force_pallas: bool = False):
    """(a @ b) mod p with padding to block multiples; exact (M, N) output."""
    if not (USE_PALLAS or force_pallas):
        return ref.modmatmul(a, b)
    m, n = a.shape[0], b.shape[1]
    bm = bm or min(_mm.DEFAULT_BM, max(8, a.shape[0]))
    bn = bn or min(_mm.DEFAULT_BN, max(8, b.shape[1]))
    bk = bk or min(_mm.DEFAULT_BK, max(8, a.shape[1]))
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    b, _ = _pad_to(b, 0, bk)
    b, _ = _pad_to(b, 1, bn)
    out = _mm.modmatmul(a, b, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return out[:m, :n]


# historical alias: modmatmul itself now returns the exact shape
modmatmul_exact = modmatmul


def modmatmul_batched(a, b, *, bm=None, bn=None, bk=None,
                      force_pallas: bool = False):
    """(a[i] @ b[i]) mod p over a leading batch axis, exact (B, M, N) out.

    One (B, M/bm, N/bn, K/bk)-grid pallas_call instead of B launches.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.modmatmul_batched(a, b)
    m, n = a.shape[1], b.shape[2]
    bm = bm or min(_mm.DEFAULT_BM, max(8, m))
    bn = bn or min(_mm.DEFAULT_BN, max(8, n))
    bk = bk or min(_mm.DEFAULT_BK, max(8, a.shape[2]))
    a, _ = _pad_to(a, 1, bm)
    a, _ = _pad_to(a, 2, bk)
    b, _ = _pad_to(b, 1, bk)
    b, _ = _pad_to(b, 2, bn)
    out = _mm.modmatmul_batched(a, b, bm=bm, bn=bn, bk=bk,
                                interpret=INTERPRET)
    return out[:, :m, :n]


def poly_eval(z, coeffs, *, block=None, force_pallas: bool = False):
    """Elementwise ghat(z) over F_p for any-shape z."""
    if not (USE_PALLAS or force_pallas):
        return ref.poly_eval(z, coeffs)
    shape = z.shape
    flat = z.reshape(-1)
    block = block or min(_fp.DEFAULT_BLOCK, max(8, flat.shape[0]))
    flat, pad = _pad_to(flat, 0, block)
    out = _fp.poly_eval(flat, coeffs, block=block, interpret=INTERPRET)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)


def coded_gradient(x: Coded, w: Coded, coeffs: Public, *, bm=None, dc=None,
                   force_pallas: bool = False) -> Coded:
    """Fused f = x^T ghat(x w) over F_p (COPML Eq. 7)."""
    if not (USE_PALLAS or force_pallas):
        return ref.coded_gradient(x, w, coeffs)
    d0 = x.shape[1]
    bm = bm or min(_cg.DEFAULT_BM, max(8, x.shape[0]))
    dc = dc or min(_cg.DEFAULT_DC, max(8, d0))
    x, _ = _pad_to(x, 0, bm)
    x, dpad = _pad_to(x, 1, dc)
    w, _ = _pad_to(w, 0, dc)
    out = _cg.coded_gradient(x, w, coeffs, bm=bm, dc=dc, interpret=INTERPRET)
    return out[:d0] if dpad else out


def coded_gradient_batched(x: Coded, w: Coded, coeffs: Public, *, bm=None,
                           dc=None, force_pallas: bool = False) -> Coded:
    """f[n] = x[n]^T ghat(x[n] w[n]) for all N clients in ONE kernel launch.

    x: (N, m, d); w: (N, d); coeffs shared.  This is COPML's whole Phase-3
    round (every client's Eq. 7 evaluation) as a single (N, m/bm) grid.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.coded_gradient_batched(x, w, coeffs)
    d0 = x.shape[2]
    tbm, tdc = pick_blocks(x.shape[1], d0)
    bm = bm or tbm
    dc = dc or tdc
    x, _ = _pad_to(x, 1, bm)
    x, dpad = _pad_to(x, 2, dc)
    w, _ = _pad_to(w, 1, dc)
    out = _cg.coded_gradient_batched(x, w, coeffs, bm=bm, dc=dc,
                                     interpret=INTERPRET)
    return out[:, :d0] if dpad else out


def coded_gradient_matrix(x: Coded, w: Coded, coeffs: Public, *, bm=None,
                          dc=None, force_pallas: bool = False) -> Coded:
    """f[n] = x[n]^T ghat(x[n] @ w[n]) for MATRIX models w: (N, d, C).

    The class-batched Phase-3 round of a multi-class objective: one
    (N, m/bm)-grid launch computes every client's and every class's coded
    gradient as a batched GEMM pair, instead of C matvec dispatches.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.coded_gradient_matrix(x, w, coeffs)
    d0 = x.shape[2]
    tbm, tdc = pick_blocks(x.shape[1], d0, w.shape[2])
    bm = bm or tbm
    dc = dc or tdc
    x, _ = _pad_to(x, 1, bm)
    x, dpad = _pad_to(x, 2, dc)
    w, _ = _pad_to(w, 1, dc)
    out = _cg.coded_gradient_matrix(x, w, coeffs, bm=bm, dc=dc,
                                    interpret=INTERPRET)
    return out[:, :d0] if dpad else out


def fused_step(x, w, coeffs, adv_off, dfull, rvec, base, xty, wsh, radd,
               r0sh, *, q_eta: int, inv2k1: int, k1: int, bm=None, dc=None,
               force_pallas: bool = False):
    """Full COPML Phase-3/4 step (post model-encode) as ONE dispatch.

    See kernels/fused_step.py for the operand contract.  Pads only the
    sample axis m (zero rows are exact: they contribute nothing to X~^T g);
    the kernel takes d ragged.  Falls back to the phase-by-phase reference
    composition when Pallas is not requested.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.fused_step(x, w, coeffs, adv_off, dfull, rvec, base, xty,
                              wsh, radd, r0sh, q_eta=q_eta, inv2k1=inv2k1,
                              k1=k1)
    tbm, tdc = pick_blocks(x.shape[1], x.shape[2], w.shape[2])
    bm = bm or tbm
    dc = dc or min(tdc, _bucket(x.shape[2]))
    x, _ = _pad_to(x, 1, bm)
    return _fs.fused_step(x, w, coeffs, adv_off, dfull, rvec, base, xty,
                          wsh, radd, r0sh, q_eta=q_eta, inv2k1=inv2k1,
                          k1=k1, bm=bm, dc=dc, interpret=INTERPRET)
