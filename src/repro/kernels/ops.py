"""Public jit'd wrappers for the Pallas kernels: padding, dispatch, fallback.

On this CPU container the kernels run in interpret mode (the kernel body
executes exactly as written); on TPU set REPRO_PALLAS_INTERPRET=0.  Small
shapes fall back to the pure-jnp reference (padding overhead would dominate).
"""

from __future__ import annotations

import os

import jax.numpy as jnp

from . import coded_gradient as _cg
from . import field_poly as _fp
from . import modmatmul as _mm
from . import ref
from ..core.labels import Coded, Public

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"
# interpret-mode kernels are slow on CPU; route big shapes only when asked
USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") != "0"


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


def modmatmul(a, b, *, bm=None, bn=None, bk=None, force_pallas: bool = False):
    """(a @ b) mod p with padding to block multiples; exact (M, N) output."""
    if not (USE_PALLAS or force_pallas):
        return ref.modmatmul(a, b)
    m, n = a.shape[0], b.shape[1]
    bm = bm or min(_mm.DEFAULT_BM, max(8, a.shape[0]))
    bn = bn or min(_mm.DEFAULT_BN, max(8, b.shape[1]))
    bk = bk or min(_mm.DEFAULT_BK, max(8, a.shape[1]))
    a, _ = _pad_to(a, 0, bm)
    a, _ = _pad_to(a, 1, bk)
    b, _ = _pad_to(b, 0, bk)
    b, _ = _pad_to(b, 1, bn)
    out = _mm.modmatmul(a, b, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return out[:m, :n]


# historical alias: modmatmul itself now returns the exact shape
modmatmul_exact = modmatmul


def modmatmul_batched(a, b, *, bm=None, bn=None, bk=None,
                      force_pallas: bool = False):
    """(a[i] @ b[i]) mod p over a leading batch axis, exact (B, M, N) out.

    One (B, M/bm, N/bn, K/bk)-grid pallas_call instead of B launches.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.modmatmul_batched(a, b)
    m, n = a.shape[1], b.shape[2]
    bm = bm or min(_mm.DEFAULT_BM, max(8, m))
    bn = bn or min(_mm.DEFAULT_BN, max(8, n))
    bk = bk or min(_mm.DEFAULT_BK, max(8, a.shape[2]))
    a, _ = _pad_to(a, 1, bm)
    a, _ = _pad_to(a, 2, bk)
    b, _ = _pad_to(b, 1, bk)
    b, _ = _pad_to(b, 2, bn)
    out = _mm.modmatmul_batched(a, b, bm=bm, bn=bn, bk=bk,
                                interpret=INTERPRET)
    return out[:, :m, :n]


def poly_eval(z, coeffs, *, block=None, force_pallas: bool = False):
    """Elementwise ghat(z) over F_p for any-shape z."""
    if not (USE_PALLAS or force_pallas):
        return ref.poly_eval(z, coeffs)
    shape = z.shape
    flat = z.reshape(-1)
    block = block or min(_fp.DEFAULT_BLOCK, max(8, flat.shape[0]))
    flat, pad = _pad_to(flat, 0, block)
    out = _fp.poly_eval(flat, coeffs, block=block, interpret=INTERPRET)
    if pad:
        out = out[:-pad]
    return out.reshape(shape)


def coded_gradient(x: Coded, w: Coded, coeffs: Public, *, bm=None, dc=None,
                   force_pallas: bool = False) -> Coded:
    """Fused f = x^T ghat(x w) over F_p (COPML Eq. 7)."""
    if not (USE_PALLAS or force_pallas):
        return ref.coded_gradient(x, w, coeffs)
    d0 = x.shape[1]
    bm = bm or min(_cg.DEFAULT_BM, max(8, x.shape[0]))
    dc = dc or min(_cg.DEFAULT_DC, max(8, d0))
    x, _ = _pad_to(x, 0, bm)
    x, dpad = _pad_to(x, 1, dc)
    w, _ = _pad_to(w, 0, dc)
    out = _cg.coded_gradient(x, w, coeffs, bm=bm, dc=dc, interpret=INTERPRET)
    return out[:d0] if dpad else out


def coded_gradient_batched(x: Coded, w: Coded, coeffs: Public, *, bm=None,
                           dc=None, force_pallas: bool = False) -> Coded:
    """f[n] = x[n]^T ghat(x[n] w[n]) for all N clients in ONE kernel launch.

    x: (N, m, d); w: (N, d); coeffs shared.  This is COPML's whole Phase-3
    round (every client's Eq. 7 evaluation) as a single (N, m/bm) grid.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.coded_gradient_batched(x, w, coeffs)
    d0 = x.shape[2]
    bm = bm or min(_cg.DEFAULT_BM, max(8, x.shape[1]))
    dc = dc or min(_cg.DEFAULT_DC, max(8, d0))
    x, _ = _pad_to(x, 1, bm)
    x, dpad = _pad_to(x, 2, dc)
    w, _ = _pad_to(w, 1, dc)
    out = _cg.coded_gradient_batched(x, w, coeffs, bm=bm, dc=dc,
                                     interpret=INTERPRET)
    return out[:, :d0] if dpad else out


def coded_gradient_matrix(x: Coded, w: Coded, coeffs: Public, *, bm=None,
                          dc=None, force_pallas: bool = False) -> Coded:
    """f[n] = x[n]^T ghat(x[n] @ w[n]) for MATRIX models w: (N, d, C).

    The class-batched Phase-3 round of a multi-class objective: one
    (N, m/bm)-grid launch computes every client's and every class's coded
    gradient as a batched GEMM pair, instead of C matvec dispatches.
    """
    if not (USE_PALLAS or force_pallas):
        return ref.coded_gradient_matrix(x, w, coeffs)
    d0 = x.shape[2]
    bm = bm or min(_cg.DEFAULT_BM, max(8, x.shape[1]))
    dc = dc or min(_cg.DEFAULT_DC, max(8, d0))
    x, _ = _pad_to(x, 1, bm)
    x, dpad = _pad_to(x, 2, dc)
    w, _ = _pad_to(w, 1, dc)
    out = _cg.coded_gradient_matrix(x, w, coeffs, bm=bm, dc=dc,
                                    interpret=INTERPRET)
    return out[:, :d0] if dpad else out
