"""Pure-jnp oracles for every Pallas kernel (no pallas_call anywhere).

These are the ground truth for the per-kernel allclose sweeps in
tests/test_kernels.py; field.np_matmul (numpy uint64, the paper's own 64-bit
lazy-reduction arithmetic) backs them up as a second, independent oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import field
from ..core.labels import Coded, Public


def modmatmul(a, b):
    """(a @ b) mod p -- jnp limb algorithm from core.field."""
    return field.matmul(a, b)


def modmatmul_batched(a, b):
    """(a[i] @ b[i]) mod p over a leading batch axis."""
    return jax.vmap(field.matmul)(a, b)


def poly_eval(z, coeffs):
    """Horner over F_p."""
    return field.evaluate_poly_dyn(coeffs, z)


def coded_gradient(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """f = x^T ghat(x w) over F_p, unfused two-pass reference."""
    z = field.matmul(x, w[:, None])[:, 0]
    g = field.evaluate_poly_dyn(coeffs, z)
    return field.matmul(x.T, g[:, None])[:, 0]


def coded_gradient_vmap(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """Per-client baseline: vmap of the single-client reference.

    Kept as the benchmark baseline and as a second oracle for the batched
    implementations (they must agree element-for-element mod p)."""
    return jax.vmap(lambda xi, wi: coded_gradient(xi, wi, coeffs))(x, w)


def coded_gradient_batched(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """f[n] = x[n]^T ghat(x[n] w[n]) for all clients; coeffs shared.

    Both passes use field.matvec_batched (limb-packed batched GEMM), which
    beats the per-client vmap by reshaping 16 n=1 matvecs per client into
    one well-shaped batched matmul."""
    z = field.matvec_batched(x, w)                       # (N, m)
    g = field.evaluate_poly_dyn(coeffs, z)
    return field.matvec_batched(jnp.swapaxes(x, 1, 2), g)  # (N, d)


def coded_gradient_matrix(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """f[n] = x[n]^T ghat(x[n] @ w[n]) for a MATRIX model w: (N, d, C).

    The class-batched hot loop: the matvec pair of the vector path becomes
    a batched GEMM pair with C columns in the free dimension (far better
    arithmetic intensity than C matvec dispatches over the same x), and
    ghat is evaluated elementwise over the whole (N, m, C) logit block.
    """
    z = jax.vmap(field.matmul)(x, w)                        # (N, m, C)
    g = field.evaluate_poly_dyn(coeffs, z)
    return jax.vmap(field.matmul)(jnp.swapaxes(x, 1, 2), g)  # (N, d, C)
