"""Pure-jnp oracles for every Pallas kernel (no pallas_call anywhere).

These are the ground truth for the per-kernel allclose sweeps in
tests/test_kernels.py; field.np_matmul (numpy uint64, the paper's own 64-bit
lazy-reduction arithmetic) backs them up as a second, independent oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import field
from ..core.labels import Coded, Public


def modmatmul(a, b):
    """(a @ b) mod p -- jnp limb algorithm from core.field."""
    return field.matmul(a, b)


def modmatmul_batched(a, b):
    """(a[i] @ b[i]) mod p over a leading batch axis."""
    return jax.vmap(field.matmul)(a, b)


def poly_eval(z, coeffs):
    """Horner over F_p."""
    return field.evaluate_poly_dyn(coeffs, z)


def coded_gradient(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """f = x^T ghat(x w) over F_p, unfused two-pass reference."""
    z = field.matmul(x, w[:, None])[:, 0]
    g = field.evaluate_poly_dyn(coeffs, z)
    return field.matmul(x.T, g[:, None])[:, 0]


def coded_gradient_vmap(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """Per-client baseline: vmap of the single-client reference.

    Kept as the benchmark baseline and as a second oracle for the batched
    implementations (they must agree element-for-element mod p)."""
    return jax.vmap(lambda xi, wi: coded_gradient(xi, wi, coeffs))(x, w)


def coded_gradient_batched(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """f[n] = x[n]^T ghat(x[n] w[n]) for all clients; coeffs shared.

    Both passes use field.matvec_batched (limb-packed batched GEMM), which
    beats the per-client vmap by reshaping 16 n=1 matvecs per client into
    one well-shaped batched matmul."""
    z = field.matvec_batched(x, w)                       # (N, m)
    g = field.evaluate_poly_dyn(coeffs, z)
    return field.matvec_batched(jnp.swapaxes(x, 1, 2), g)  # (N, d)


def coded_gradient_matrix(x: Coded, w: Coded, coeffs: Public) -> Coded:
    """f[n] = x[n]^T ghat(x[n] @ w[n]) for a MATRIX model w: (N, d, C).

    The class-batched hot loop: the matvec pair of the vector path becomes
    a batched GEMM pair with C columns in the free dimension (far better
    arithmetic intensity than C matvec dispatches over the same x), and
    ghat is evaluated elementwise over the whole (N, m, C) logit block.
    """
    z = jax.vmap(field.matmul)(x, w)                        # (N, m, C)
    g = field.evaluate_poly_dyn(coeffs, z)
    return jax.vmap(field.matmul)(jnp.swapaxes(x, 1, 2), g)  # (N, d, C)


def fused_step(x, w, coeffs, adv_off, dfull, rvec, base, xty, wsh, radd,
               r0sh, *, q_eta: int, inv2k1: int, k1: int):
    """Phase-by-phase oracle for kernels.fused_step (same operands/returns).

    Composes the existing references in protocol order: matrix coded
    gradient, corruption offset, decode fold against the zero-scattered
    decode row, q_eta scale, TruncPr masked open (rvec = the reconstruct
    Lagrange row zero-padded over holders) and borrow-folded rescale.
    """
    n = x.shape[0]
    f = coded_gradient_matrix(x, w, coeffs)
    f_adj = field.add(f, adv_off[:, None, None])
    common = field.matmul(
        dfull[None], f_adj.reshape(n, -1))[0].reshape(f.shape[1:])
    xtg = field.add(base, common[None])
    grad = field.sub(xtg, xty)
    scaled = field.mul_scalar(grad, q_eta)
    c_sh = field.add(scaled, radd)
    c_open = field.matmul(
        rvec[None], c_sh.reshape(n, -1))[0].reshape(c_sh.shape[1:])
    c0 = jnp.bitwise_and(c_open, (1 << k1) - 1)
    a0 = field.sub(jnp.broadcast_to(c0[None], c_sh.shape), r0sh)
    delta = field.mul_scalar(field.sub(scaled, a0), inv2k1)
    return f, field.sub(wsh, delta)
