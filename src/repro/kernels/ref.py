"""Pure-jnp oracles for every Pallas kernel (no pallas_call anywhere).

These are the ground truth for the per-kernel allclose sweeps in
tests/test_kernels.py; field.np_matmul (numpy uint64, the paper's own 64-bit
lazy-reduction arithmetic) backs them up as a second, independent oracle.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..core import field


def modmatmul(a, b):
    """(a @ b) mod p -- jnp limb algorithm from core.field."""
    return field.matmul(a, b)


def poly_eval(z, coeffs):
    """Horner over F_p."""
    return field.evaluate_poly_dyn(coeffs, z)


def coded_gradient(x, w, coeffs):
    """f = x^T ghat(x w) over F_p, unfused two-pass reference."""
    z = field.matmul(x, w[:, None])[:, 0]
    g = field.evaluate_poly_dyn(coeffs, z)
    return field.matmul(x.T, g[:, None])[:, 0]
