"""Pallas TPU kernel: elementwise Horner evaluation of ghat over F_p.

VPU-bound elementwise kernel; the coefficient vector (r+1 elements, r <= 3 in
the paper) rides along in SMEM-sized VMEM and the Horner chain is unrolled
statically.  All int32 (13-bit-limb modular multiplies).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import field

DEFAULT_BLOCK = 4096


def _kernel(z_ref, c_ref, o_ref, *, degree: int):
    z = z_ref[...]
    acc = jnp.broadcast_to(c_ref[degree], z.shape)
    for i in range(degree - 1, -1, -1):
        acc = field.add(field.mul(acc, z), jnp.broadcast_to(c_ref[i], z.shape))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def poly_eval(z, coeffs, *, block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Evaluate sum_i coeffs[i] z^i over F_p elementwise.

    z: (L,) int32 field elements, L % block == 0 (ops.py pads);
    coeffs: (r+1,) int32.
    """
    (l,) = z.shape
    assert l % block == 0
    degree = coeffs.shape[0] - 1
    return pl.pallas_call(
        functools.partial(_kernel, degree=degree),
        grid=(l // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((coeffs.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((l,), jnp.int32),
        interpret=interpret,
    )(z, coeffs)
