"""Pallas TPU megakernel: ONE dispatch for a full COPML Phase-3/4 step.

The phase-siloed hot loop costs four dispatches per iteration -- gradient
GEMM pair (kernels/coded_gradient.py), decode matvec, q_eta scale, TruncPr
share arithmetic -- each with its own HBM round-trip over the (N, dw) share
state.  This kernel runs the whole post-encode step on the (N, m/bm) grid of
the batched gradient kernel and finishes the protocol arithmetic in the
kernel epilogue, so one `pallas_call` per iteration touches X~ exactly once:

  per row block (the double-buffered pipeline body):
      z = X~_blk @ W~          (limb GEMM, dc-chunked contraction)
      g = ghat(z)              (unrolled Horner, in-register on the VPU)
      f += X~_blk^T g          (limb GEMM, bm-wide contraction)
  per client (last row block):
      f_adj = f + adv_offset[n]                 (corruption injection point)
      common += dfull[n] * f_adj                (decode fold, see below)
  once (last client, last row block -- the epilogue):
      xtg    = base + common          (per-holder decode result)
      grad   = xtg - xty
      scaled = grad * q_eta           (public update constant)
      c      = open(scaled + r_sh + bias)   (TruncPr masked opening)
      delta  = (scaled - (c0 - r0_sh)) * inv(2^k1)
      w'     = w - delta

Bit-exactness with the phase-siloed path rests on two facts proven in the
property/golden tests and documented in docs/ARCHITECTURE.md:

* Decode folding.  The holder-h decode row is
  xtg[h] = sum_o dfull[o] * (mix[h,o] + f_adj[o])  where `mix` is
  shamir.share's value-INDEPENDENT masking term (its coefficients depend
  only on the key and shape).  The caller precomputes
  base[h] = sum_o dfull[o] * mix[h,o] from the same randomness stream;
  the kernel only needs the holder-independent
  common = sum_o dfull[o] * f_adj[o], accumulated across the client grid
  dimension.  `dfull` is the (R,) decode row scattered into an (N,) vector
  (zero weight = excluded client), which turns the subset gather into a
  full-length contraction -- exact mod p, and compatible with traced
  fault-plan subsets.
* TruncPr randomness.  r, [r], [r0] are value-independent draws
  (truncation.trunc_pr_randomness); the kernel receives radd = [r] + bias
  and [r0] and performs only the value-DEPENDENT close: the masked open
  c = rvec @ c_sh (rvec = the first-T+1-holders Lagrange row, zero-padded
  to N -- identical weights to shamir.reconstruct's default subset) and
  the borrow-folded rescale.

Every quantity is a canonical representative in [0, p), so any exact mod-p
evaluation order produces bit-identical int32 -- the pinned sha256 goldens
in tests/test_api.py hold with this kernel active.

Shapes are the class-batched MATRIX form (C = 1 recovers the vector path:
the limb GEMMs are literally the same dot_general calls).  d need not be a
multiple of dc (the chunk loop takes a ragged tail); m is padded to bm with
zero rows by ops.py (zero rows contribute zero to X~^T g).  VMEM budget:
the six (N, d, C) epilogue operands stay resident, so N * d * C should be
kept well under the ~16 MB/core budget (true for every paper scale).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import field
from .coded_gradient import DEFAULT_BM, DEFAULT_DC, _limb_dot_mod


def _gradient_block(x, w, c_ref, f_ref, *, degree: int, dc: int):
    """One (bm, d) row block of one client: f += X_blk^T ghat(X_blk @ W).

    Same math as coded_gradient._fused_block_matrix but tolerant of a
    ragged final d-chunk (static slicing clamps; every chunk <= dc <= 1024
    keeps the f32 limb accumulation exact)."""
    d = x.shape[1]
    z = jnp.zeros((x.shape[0], w.shape[1]), jnp.int32)
    for s in range(0, d, dc):
        z = field.add(z, _limb_dot_mod(x[:, s:s + dc], w[s:s + dc, :], 1, 0))
    g = jnp.broadcast_to(c_ref[degree], z.shape)
    for t in range(degree - 1, -1, -1):
        g = field.add(field.mul(g, z), jnp.broadcast_to(c_ref[t], z.shape))
    for s in range(0, d, dc):
        upd = _limb_dot_mod(x[:, s:s + dc], g, 0, 0)
        f_ref[0, s:s + dc, :] = field.add(f_ref[0, s:s + dc, :], upd)


def _kernel(x_ref, w_ref, c_ref, adv_ref, dfull_ref, rvec_ref, base_ref,
            xty_ref, wsh_ref, radd_ref, r0sh_ref, f_ref, common_ref,
            wout_ref, *, degree: int, dc: int, q_eta: int, inv2k1: int,
            k1: int):
    n = pl.program_id(0)                # client (outer)
    i = pl.program_id(1)                # row block (innermost)
    ncl = pl.num_programs(0)
    nblk = pl.num_programs(1)

    @pl.when(jnp.logical_and(n == 0, i == 0))
    def _init_common():
        common_ref[...] = jnp.zeros_like(common_ref)

    @pl.when(i == 0)
    def _init_f():
        f_ref[...] = jnp.zeros_like(f_ref)

    _gradient_block(x_ref[0], w_ref[0], c_ref, f_ref, degree=degree, dc=dc)

    @pl.when(i == nblk - 1)
    def _fold_client():
        # client n's gradient is complete: inject the (possibly zero)
        # corruption offset and fold into the decode accumulator with this
        # client's public decode weight (zero = excluded from the subset)
        f_adj = field.add(f_ref[0], jnp.full((), adv_ref[n], jnp.int32))
        contrib = field.mul(f_adj, jnp.full((), dfull_ref[n], jnp.int32))
        common_ref[...] = field.add(common_ref[...], contrib)

    @pl.when(jnp.logical_and(n == ncl - 1, i == nblk - 1))
    def _epilogue():
        # Phase 4 on shares, entirely in VMEM: decode + update + TruncPr
        xtg = field.add(base_ref[...], common_ref[...][None])
        grad = field.sub(xtg, xty_ref[...])
        scaled = field.mul_scalar(grad, q_eta)
        c_sh = field.add(scaled, radd_ref[...])
        nc = c_sh.shape[0]
        # masked OPEN: Lagrange row over holders (contraction N <= 1024)
        c_open = _limb_dot_mod(rvec_ref[...][None, :],
                               c_sh.reshape(nc, -1), 1, 0)[0]
        c_open = c_open.reshape(c_sh.shape[1:])
        c0 = jnp.bitwise_and(c_open, (1 << k1) - 1)
        a0 = field.sub(jnp.broadcast_to(c0[None], c_sh.shape),
                       r0sh_ref[...])
        delta = field.mul_scalar(field.sub(scaled, a0), inv2k1)
        wout_ref[...] = field.sub(wsh_ref[...], delta)


@functools.partial(
    jax.jit,
    static_argnames=("bm", "dc", "q_eta", "inv2k1", "k1", "interpret"))
def fused_step(x, w, coeffs, adv_off, dfull, rvec, base, xty, wsh, radd,
               r0sh, *, q_eta: int, inv2k1: int, k1: int,
               bm: int = DEFAULT_BM, dc: int = DEFAULT_DC,
               interpret: bool = True):
    """One COPML GD step (post model-encode) as a single pallas_call.

    x: (N, m, d) coded slices; w: (N, d, C) coded models; coeffs: (r+1,).
    adv_off/dfull/rvec: (N,) per-client corruption offsets, decode row,
    open row.  base/xty/wsh/radd/r0sh: (N, d, C) epilogue operands (see
    module docstring).  Returns (f, new_w): the per-client coded gradients
    (pre-corruption, matching coded_gradient_matrix) and the updated model
    shares.  m % bm == 0 (ops.py pads); N <= 1024 bounds the open
    contraction; d may be ragged w.r.t. dc.
    """
    nb, m, d = x.shape
    c = w.shape[2]
    assert w.shape == (nb, d, c), (x.shape, w.shape)
    assert m % bm == 0, (x.shape, bm)
    assert bm <= 1024 and dc <= 1024 and nb <= 1024
    for arr in (base, xty, wsh, radd, r0sh):
        assert arr.shape == (nb, d, c), (arr.shape, (nb, d, c))
    degree = coeffs.shape[0] - 1
    nvec = pl.BlockSpec((nb,), lambda n, i: (0,))
    full = pl.BlockSpec((nb, d, c), lambda n, i: (0, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, degree=degree, dc=dc, q_eta=q_eta,
                          inv2k1=inv2k1, k1=k1),
        grid=(nb, m // bm),
        in_specs=[
            pl.BlockSpec((1, bm, d), lambda n, i: (n, i, 0)),
            pl.BlockSpec((1, d, c), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((coeffs.shape[0],), lambda n, i: (0,)),
            nvec, nvec, nvec, full, full, full, full, full,
        ],
        out_specs=[
            pl.BlockSpec((1, d, c), lambda n, i: (n, 0, 0)),
            pl.BlockSpec((d, c), lambda n, i: (0, 0)),
            full,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, d, c), jnp.int32),    # f
            jax.ShapeDtypeStruct((d, c), jnp.int32),        # common
            jax.ShapeDtypeStruct((nb, d, c), jnp.int32),    # new_w
        ],
        interpret=interpret,
    )(x, w, coeffs, adv_off, dfull, rvec, base, xty, wsh, radd, r0sh)[::2]
