"""Optimizers (pure JAX): AdamW, SGD-momentum, and factored Adafactor.

Adafactor matters at assigned-architecture scale: arctic-480b's unfactored
AdamW f32 states (~5.8 TB) cannot fit a v5e-256 pod; the factored second
moment (row+col statistics) reduces optimizer memory to ~O(params/d).

Each optimizer is (init(params) -> state, update(grads, state, params, step)
-> (new_params, new_state)).  Gradient clipping and int8 DP-axis gradient
compression hooks live here too (distributed-optimization tricks).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9
    clip_norm: float = 1.0


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), norm


def adamw(cfg: OptConfig) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - cfg.b1 ** t
        bc2 = 1.0 - cfg.b2 ** t

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * gf
            v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
            step_ = cfg.lr * (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            if p.ndim >= 2:
                step_ = step_ + cfg.lr * cfg.weight_decay * \
                    p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, gnorm

    return Optimizer(init, update)


def sgdm(cfg: OptConfig) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)

        def upd(g, m, p):
            m = cfg.momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - cfg.lr * m).astype(p.dtype), m

        out = jax.tree.map(upd, grads, state["m"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m}, gnorm

    return Optimizer(init, update)


def adafactor(cfg: OptConfig) -> Optimizer:
    """Factored second moment; no first moment, no f32 master copy."""

    def init(params):
        def make(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(make, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8

        def upd(g, s, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + 1e-30
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :] /
                    jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                1e-30)[..., None]) + cfg.eps
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                denom = jnp.sqrt(v) + cfg.eps
                ns = {"v": v}
            step_ = cfg.lr * gf / denom
            if p.ndim >= 2:
                step_ = step_ + cfg.lr * cfg.weight_decay * \
                    p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step_).astype(p.dtype), ns

        # state leaves are dicts, so map over the params structure manually
        flat_p, td = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree_util.tree_leaves(grads)
        flat_s = td.flatten_up_to(state["f"])
        res = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = td.unflatten([r[0] for r in res])
        new_f = td.unflatten([r[1] for r in res])
        return new_params, {"f": new_f}, gnorm

    return Optimizer(init, update)


def make(name: str, cfg: OptConfig | None = None) -> Optimizer:
    cfg = cfg or OptConfig(name=name)
    return {"adamw": adamw, "sgdm": sgdm, "adafactor": adafactor}[name](cfg)
