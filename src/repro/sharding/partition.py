"""Partition rules: map model/optimizer/input pytrees onto the mesh.

LM/serving axes: ("pod",) "data", "model"; the distributed COPML engine adds
a 1-D "clients" axis (copml_state_structs below; docs/ARCHITECTURE.md maps
each protocol phase onto its collective).  Rules (DESIGN.md section 5):
  * params: from the model's own param table (models/model.py)
  * optimizer state: derived per-leaf from the param spec (adafactor's
    factored stats drop the corresponding dim)
  * batch: ("pod","data") on the batch dim
  * decode KV caches: batch on "data", cache sequence on "model"
    (GQA kv-head counts need not divide the model axis; sequence always
    does).  long_500k (batch=1): sequence on "data" AND heads on "model".
Axes absent from the mesh (or not dividing the dim) are dropped.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import model as M
from ..models import model_zoo as MZ
from ..models.config import ModelConfig, ShapeConfig


def _fit(spec_entry, dim, mesh: Mesh):
    """Keep a spec axis only if present in the mesh and dividing the dim."""
    if spec_entry is None:
        return None
    entries = spec_entry if isinstance(spec_entry, tuple) else (spec_entry,)
    kept = tuple(a for a in entries if a in mesh.shape)
    size = int(np.prod([mesh.shape[a] for a in kept])) if kept else 1
    if not kept or dim % size != 0:
        return None
    return kept if len(kept) > 1 else kept[0]


def normalize(spec: P, shape, mesh: Mesh) -> P:
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    return P(*(_fit(e, d, mesh) for e, d in zip(entries, shape)))


def shard(mesh: Mesh, spec: P, shape) -> NamedSharding:
    return NamedSharding(mesh, normalize(spec, shape, mesh))


def param_shardings(cfg: ModelConfig, mesh: Mesh) -> dict:
    table = M.param_table(cfg)
    return {k: shard(mesh, P(*v.spec), v.shape) for k, v in table.items()}


def param_structs(cfg: ModelConfig, mesh: Mesh,
                  fsdp: bool | None = None) -> dict:
    """Abstract params with shardings attached (dry-run inputs).

    fsdp=True additionally shards every >=2D parameter's largest free dim
    over the 'data' axis (ZeRO-3/FSDP via GSPMD: weights are all-gathered
    per layer inside the step).  Default: on when the model-parallel shard
    alone exceeds ~4 GiB/device (arctic-480b), and for decode/prefill cells
    where the data axis carries no gradient state (launch/dryrun.py)."""
    table = M.param_table(cfg)
    if fsdp is None:
        model_shards = mesh.shape.get("model", 1)
        bytes_per_dev = cfg.param_count() * 2 / model_shards
        fsdp = bytes_per_dev > 4 * 2 ** 30
    out = {}
    for k, v in table.items():
        dt = jnp.dtype(v.dtype) if v.dtype else cfg.jdtype
        sp = tuple(v.spec)
        if fsdp and len(v.shape) >= 2:
            sp = zero_spec(sp, v.shape, mesh)
        out[k] = jax.ShapeDtypeStruct(
            v.shape, dt, sharding=shard(mesh, P(*sp), v.shape))
    return out


def zero_spec(spec: tuple, shape: tuple, mesh: Mesh) -> tuple:
    """ZeRO-style optimizer-state sharding: additionally shard the largest
    dim not already sharded over the 'data' axis.  Distributed-optimization
    trick from DESIGN.md section 5: unfactored f32 moments of a 30B+ MoE do
    not fit HBM when sharded on 'model' only."""
    if "data" not in mesh.shape:
        return spec
    sp = list(tuple(spec) + (None,) * (len(shape) - len(tuple(spec))))
    data = mesh.shape["data"]
    best, best_dim = None, 0
    for i, (e, d) in enumerate(zip(sp, shape)):
        if e is None and d % data == 0 and d > best_dim:
            best, best_dim = i, d
    if best is not None:
        sp[best] = "data"
    return tuple(sp)


def opt_state_structs(cfg: ModelConfig, mesh: Mesh, params: dict) -> Any:
    """Abstract optimizer state with derived (ZeRO-sharded) shardings."""
    table = M.param_table(cfg)

    def f32(shape, sp):
        sp = zero_spec(sp, shape, mesh)
        return jax.ShapeDtypeStruct(
            shape, jnp.float32, sharding=shard(mesh, P(*sp), shape))

    if cfg.optimizer in ("adamw", "sgdm"):
        moments = {k: f32(table[k].shape, table[k].spec) for k in table}
        if cfg.optimizer == "adamw":
            return {"m": moments,
                    "v": {k: f32(table[k].shape, table[k].spec)
                          for k in table}}
        return {"m": moments}
    # adafactor
    fstate = {}
    for k, v in table.items():
        if len(v.shape) >= 2:
            fstate[k] = {
                "vr": f32(v.shape[:-1], tuple(v.spec)[:-1]),
                "vc": f32(v.shape[:-2] + v.shape[-1:],
                          tuple(v.spec)[:-2] + tuple(v.spec)[-1:]),
            }
        else:
            fstate[k] = {"v": f32(v.shape, v.spec)}
    return {"f": fstate}


def batch_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> dict:
    specs = MZ.input_specs(cfg, shape)
    batch_axes = ("pod", "data")
    out = {}
    for k, sds in specs.items():
        sp = (batch_axes,) + (None,) * (len(sds.shape) - 1)
        out[k] = jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=shard(mesh, P(*sp), sds.shape))
    return out


def cache_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """Abstract decode caches with shardings (see module docstring)."""
    b, s = shape.global_batch, shape.seq_len
    long = b < mesh.shape.get("data", 1)      # can't shard batch: long_500k
    caches = MZ.init_cache(cfg, b, s, abstract=True)

    def kv_spec(ndim, seq_axis, batch_axis, head_axis):
        sp = [None] * ndim
        if long:
            sp[seq_axis] = "data"
            sp[head_axis] = "model"
        else:
            sp[batch_axis] = "data"
            sp[seq_axis] = "model"
        return sp

    def annotate(path, sds):
        nd = len(sds.shape)
        sp = [None] * nd
        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            # (L, B, S, Hkv, hd); encdec cross caches have S = encoder_seq
            sp = kv_spec(nd, 2, 1, 3)
        elif cfg.family == "ssm":
            # conv (L,B,K-1,di) / h (L,B,di,ns): shard di on model
            sp = [None] * nd
            sp[1] = None if long else "data"
            di_axis = 3 if nd == 4 and sds.shape[3] == cfg.d_inner else 2
            if sds.shape[di_axis] == cfg.d_inner:
                sp[di_axis] = "model"
        elif cfg.family == "hybrid":
            if nd == 5 and sds.shape[2] == s:     # attn kv (g,B,S,H,hd)
                sp = kv_spec(nd, 2, 1, 3)
            else:
                # mamba conv (g,a,B,K-1,di) / h (g,a,B,nh,hd,ns)
                sp = [None] * nd
                sp[2] = None if long else "data"
                for ax, dim in enumerate(sds.shape):
                    if ax >= 3 and dim in (cfg.d_inner, cfg.mamba2_heads):
                        sp[ax] = "model"
                        break
        return jax.ShapeDtypeStruct(
            sds.shape, sds.dtype,
            sharding=shard(mesh, P(*sp), sds.shape))

    return jax.tree.map(lambda x: annotate(None, x), caches)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def copml_state_structs(proto, mesh: Mesh):
    """Abstract CopmlState for the sharded COPML engine.

    The client axis is zero-padded to a multiple of the mesh size and split
    one block per device -- the exact input layout Copml.train_sharded /
    Copml.sharded_step consume.  Used by launch/copml_dist.dryrun_cell to
    lower the real collective program without materializing data.
    """
    from ..core.protocol import CopmlState
    n, d = proto.cfg.n_clients, proto.d
    n_pad = -(-n // mesh.size) * mesh.size
    mk = -(-proto.m // proto.cfg.k)
    cl = NamedSharding(mesh, P(tuple(mesh.axis_names)))
    sds = lambda shape: jax.ShapeDtypeStruct(shape, jnp.int32, sharding=cl)
    return CopmlState(
        w_shares=sds((n_pad,) + proto.w_shape),
        coded_x=sds((n_pad, mk, d)),
        xty_shares=sds((n_pad,) + proto.w_shape),
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=replicated(mesh)),
    )
