"""Deterministic synthetic data pipeline.

Real corpora are not available offline; the pipeline is nonetheless built
like a production loader: deterministic per-step batches keyed by (seed,
step) so a restarted job replays the exact same stream (fault-tolerance
requirement -- checkpoint restore + step counter == exact continuation),
host-sharded so each data-parallel host materializes only its slice.

Generators:
  * lm_batch: token streams with Zipfian unigram statistics + a repeated
    n-gram structure so the LM loss actually decreases.
  * classification: the paper's (m, d) binary tasks: two Gaussian classes
    with a planted separator (CIFAR-10-scale / GISETTE-scale stand-ins,
    Section V-A).
  * multiclass: C Gaussian clusters with integer labels (MNIST-scale
    stand-in for the one-vs-rest objective).
  * regression: y = x @ w* + noise for the linreg objective.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class LmDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


def lm_batch(cfg: LmDataConfig, step: int, *, host_slice=None) -> dict:
    """Batch for `step`, deterministic in (seed, step).

    host_slice: (start, size) rows for this host (None = all rows).
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    b, s = cfg.global_batch, cfg.seq_len
    if host_slice is not None:
        start, size = host_slice
        key = jax.random.fold_in(key, start)
        b = size
    # Zipf-ish unigrams via exponentiated uniforms (cheap, deterministic)
    u = jax.random.uniform(key, (b, s + 1), minval=1e-6, maxval=1.0)
    ranks = (u ** (-1.0 / cfg.zipf_a)).astype(jnp.float32)
    tokens = jnp.clip(ranks.astype(jnp.int32), 0, cfg.vocab - 1)
    # plant learnable structure: every even position repeats its predecessor
    # shifted by one (the model can reach well below unigram entropy)
    pos = jnp.arange(s + 1)
    tokens = jnp.where((pos % 2 == 0)[None, :],
                       jnp.roll(tokens, 1, axis=1) + 1, tokens)
    tokens = jnp.clip(tokens, 0, cfg.vocab - 1)
    return {"tokens": tokens[:, :-1],
            "labels": tokens[:, 1:],
            "mask": jnp.ones((b, s), jnp.float32)}


def classification_dataset(m: int, d: int, seed: int = 0,
                           margin: float = 2.0, test_m: int = 0):
    """Two-class Gaussian task with a planted separator; features in [-1, 1].

    Returns (X, y[, X_test, y_test]).  Accuracy of float logistic regression
    lands around the paper's 80-97% range depending on `margin`.
    """
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=d) / np.sqrt(d)
    total = m + test_m
    x = np.clip(rng.normal(size=(total, d)) * 0.5, -1, 1)
    logits = x @ w_star * margin * np.sqrt(d)
    y = (1 / (1 + np.exp(-logits)) > rng.uniform(size=total)).astype(
        np.float32)
    if test_m:
        return (x[:m], y[:m], x[m:], y[m:])
    return x[:m], y[:m]


def multiclass_dataset(m: int, d: int, n_classes: int, seed: int = 0,
                       margin: float = 1.4, test_m: int = 0):
    """C Gaussian clusters with planted unit class directions (MNIST-scale
    stand-in for the one-vs-rest objective); features in [-1, 1].

    Returns (X, y[, X_test, y_test]) with y integer class labels in
    [0, C).  `margin` is the cluster-mean norm in noise-std units (0.5):
    argmax accuracy of one-vs-rest logistic regression rises from chance
    toward 1 as margin grows past ~1.
    """
    rng = np.random.default_rng(seed)
    mu = rng.normal(size=(n_classes, d))
    mu /= np.linalg.norm(mu, axis=1, keepdims=True)       # unit directions
    total = m + test_m
    y = rng.integers(0, n_classes, size=total)
    x = np.clip(mu[y] * margin * 0.5 + rng.normal(size=(total, d)) * 0.5,
                -1, 1).astype(np.float64)
    y = y.astype(np.int32)
    if test_m:
        return x[:m], y[:m], x[m:], y[m:]
    return x[:m], y[:m]


def regression_dataset(m: int, d: int, seed: int = 0, noise: float = 0.1,
                       test_m: int = 0):
    """Linear-regression task y = x @ w* + noise; features in [-1, 1] and
    |y| small enough for the protocol's 2^lg target quantization."""
    rng = np.random.default_rng(seed)
    w_star = rng.normal(size=d) / np.sqrt(d)
    total = m + test_m
    x = np.clip(rng.normal(size=(total, d)) * 0.5, -1, 1)
    y = (x @ w_star + noise * rng.normal(size=total)).astype(np.float32)
    if test_m:
        return x[:m], y[:m], x[m:], y[m:]
    return x[:m], y[:m]


def split_clients(x, y, n: int):
    """Distribute rows evenly across N clients (paper Section V-A)."""
    idx = np.array_split(np.arange(x.shape[0]), n)
    return [x[i] for i in idx], [y[i] for i in idx]
